//! Static plan verifier — a lint pass over descriptor tables, fusion
//! bindings, and cycle accounting.
//!
//! Every invariant the runtime enforces dynamically (DRAM region bounds,
//! dataflow chaining, fusion-binding disjointness, the shared residency
//! budget, `overlapped ≤ min(compute, mem)`) is re-derived here
//! **statically**: a descriptor table or a compiled plan is checked
//! without executing a single simulated cycle, and every violation comes
//! back as a typed [`Diagnostic`] with a stable code. `Driver::compile`
//! rejects Error-level plans with [`crate::error::Error::PlanVerify`],
//! the `kom-accel lint` subcommand prints diagnostics for any network ×
//! batch × shards × fusion combination, and Warn-level counts ride along
//! in `RunMetrics::verify_warnings`.
//!
//! The checks deliberately do **not** call the fusion planner or the SoC:
//! the budget arithmetic, the cycle lower bounds and the encoding layout
//! are re-derived independently, so a bug in the planner or the cycle
//! model cannot self-certify.
//!
//! ## Diagnostic codes
//!
//! | code | severity | meaning |
//! |---|---|---|
//! | `KOM-E001` | Error | a layer's weight region overlaps another live DRAM region |
//! | `KOM-E002` | Error | a weight/input/output region is out of DRAM bounds |
//! | `KOM-E003` | Error | consumer input and producer output intersect without chaining exactly |
//! | `KOM-E004` | Error | adjacent fused resident bindings overlap |
//! | `KOM-E005` | Error | fused binding inside a DMA staging bank / outside the scratchpad |
//! | `KOM-E006` | Error | resident + cacheable-weight footprint exceeds the residency budget |
//! | `KOM-E007` | Error | descriptor encoding does not round-trip / image or program disagree |
//! | `KOM-E008` | Error | fusion side-band carries an unknown encoding version |
//! | `KOM-E009` | Error | plan handle is stale (compiled at an older arena epoch) |
//! | `KOM-E010` | Error | plan handle was compiled by a different driver |
//! | `KOM-E011` | Error | table does not fit control RAM / batch outside register range |
//! | `KOM-E012` | Error | degenerate geometry or an inconsistent static cycle model |
//! | `KOM-W001` | Warn | consecutive layers are not dataflow-chained (disjoint regions) |
//! | `KOM-W002` | Warn | FIR demo layer in a batched (`batch > 1`) table |

use super::desc::{FusionCtl, LayerDesc, DESC_WORDS, FUSION_ENC_VERSION};
use super::soc::SocConfig;
use crate::cnn::layers::{Layer, LayerShape};
use std::fmt;

/// Stable diagnostic codes — never renumber, only append.
pub mod codes {
    /// A layer's weight region overlaps another live DRAM region.
    pub const OVERLAPPING_DRAM_REGIONS: &str = "KOM-E001";
    /// A weight/input/output region is out of DRAM bounds.
    pub const REGION_OUT_OF_BOUNDS: &str = "KOM-E002";
    /// Consumer input and producer output intersect without chaining exactly.
    pub const BROKEN_DATAFLOW_CHAIN: &str = "KOM-E003";
    /// Adjacent fused resident bindings overlap.
    pub const FUSION_BINDING_OVERLAP: &str = "KOM-E004";
    /// Fused binding inside a DMA staging bank or outside the scratchpad.
    pub const FUSION_BINDING_IN_STAGING_BANK: &str = "KOM-E005";
    /// Resident + cacheable-weight footprint exceeds the residency budget.
    pub const FUSION_BUDGET_EXCEEDED: &str = "KOM-E006";
    /// Descriptor encoding does not round-trip / image or program disagree.
    pub const ENCODING_MISMATCH: &str = "KOM-E007";
    /// Fusion side-band carries an unknown encoding version.
    pub const BAD_FUSION_SIDEBAND_VERSION: &str = "KOM-E008";
    /// Plan handle is stale (compiled at an older arena epoch).
    pub const STALE_PLAN: &str = "KOM-E009";
    /// Plan handle was compiled by a different driver.
    pub const FOREIGN_PLAN: &str = "KOM-E010";
    /// Table does not fit control RAM / batch outside the register range.
    pub const TABLE_TOO_LARGE: &str = "KOM-E011";
    /// Degenerate geometry or an inconsistent static cycle model.
    pub const DEGENERATE_GEOMETRY: &str = "KOM-E012";
    /// Consecutive layers are not dataflow-chained (disjoint regions).
    pub const UNCHAINED_LAYERS: &str = "KOM-W001";
    /// FIR demo layer in a batched (`batch > 1`) table.
    pub const FIR_IN_BATCHED_TABLE: &str = "KOM-W002";
}

/// How bad a finding is: `Error` makes `Driver::compile` reject the plan,
/// `Warn` is surfaced in metrics (and fails `lint --deny-warnings`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Suspicious but executable.
    Warn,
    /// The plan must not execute.
    Error,
}

/// One static-analysis finding over a descriptor table or compiled plan.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Stable code from [`codes`] (e.g. `KOM-E001`).
    pub code: &'static str,
    /// Error-level findings reject the plan; Warn-level ride along.
    pub severity: Severity,
    /// Offending layer index, when the finding is layer-local.
    pub layer: Option<usize>,
    /// Human-readable description with the offending numbers.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warn => "warning",
        };
        match self.layer {
            Some(i) => write!(f, "{} {sev} [layer {i}]: {}", self.code, self.message),
            None => write!(f, "{} {sev}: {}", self.code, self.message),
        }
    }
}

fn error(code: &'static str, layer: Option<usize>, message: String) -> Diagnostic {
    Diagnostic {
        code,
        severity: Severity::Error,
        layer,
        message,
    }
}

fn warn(code: &'static str, layer: Option<usize>, message: String) -> Diagnostic {
    Diagnostic {
        code,
        severity: Severity::Warn,
        layer,
        message,
    }
}

/// True when any diagnostic is Error-level (the plan must be rejected).
pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

/// Number of Warn-level diagnostics.
pub fn warn_count(diags: &[Diagnostic]) -> usize {
    diags.iter().filter(|d| d.severity == Severity::Warn).count()
}

/// Run every static check on a table + its fusion side-bands + its encoded
/// ctrl-RAM image: the verdict `Driver::compile` acts on.
pub fn verify_all(
    descs: &[LayerDesc],
    ctls: &[FusionCtl],
    batch: u32,
    image: &[u32],
    cfg: &SocConfig,
) -> Vec<Diagnostic> {
    let mut diags = verify_table(descs, batch, cfg);
    diags.extend(verify_fusion(descs, ctls, cfg));
    diags.extend(verify_image(descs, ctls, image));
    diags
}

/// Checks (a), (b) and (e): region bounds/aliasing, dataflow chaining,
/// geometry vs the `cnn::layers` analytical dims, table sizing and the
/// static cycle model.
pub fn verify_table(descs: &[LayerDesc], batch: u32, cfg: &SocConfig) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    check_config(cfg, &mut diags);
    check_table_size(descs.len(), batch, cfg, &mut diags);
    let lens = check_geometry(descs, &mut diags);
    check_regions(descs, &lens, batch, cfg, &mut diags);
    check_chain(descs, &lens, batch, &mut diags);
    check_cycles(descs, &lens, batch, cfg, &mut diags);
    diags
}

/// A `(per-image input words, per-image output words)` pair per layer, or
/// `None` when the layer's geometry is degenerate — descriptor-held
/// geometry is never trusted before passing through here, because
/// `LayerDesc::{in_len,out_len}` divide by the descriptor's own stride
/// and subtract its own kernel size.
type LayerLens = Vec<Option<(u64, u64)>>;

fn layer_lens(d: &LayerDesc) -> Option<(u64, u64)> {
    match *d {
        LayerDesc::Conv {
            cout,
            cin,
            k,
            stride,
            pad,
            h,
            w,
            ..
        } => {
            if cout == 0 || cin == 0 || k == 0 || stride == 0 || h == 0 || w == 0 {
                return None;
            }
            let (hp, wp) = (h as u64 + 2 * pad as u64, w as u64 + 2 * pad as u64);
            if hp < k as u64 || wp < k as u64 {
                return None;
            }
            let ho = (hp - k as u64) / stride as u64 + 1;
            let wo = (wp - k as u64) / stride as u64 + 1;
            Some((
                cin as u64 * h as u64 * w as u64,
                cout as u64 * ho * wo,
            ))
        }
        LayerDesc::Pool {
            k, stride, c, h, w, ..
        } => {
            if k == 0 || stride == 0 || c == 0 || (h as u64) < k as u64 || (w as u64) < k as u64 {
                return None;
            }
            let ho = (h as u64 - k as u64) / stride as u64 + 1;
            let wo = (w as u64 - k as u64) / stride as u64 + 1;
            Some((c as u64 * h as u64 * w as u64, c as u64 * ho * wo))
        }
        LayerDesc::Fc { n_in, n_out, .. } => {
            if n_in == 0 || n_out == 0 {
                return None;
            }
            Some((n_in as u64, n_out as u64))
        }
        LayerDesc::Fir { n_taps, n, .. } => {
            if n_taps == 0 || n == 0 {
                return None;
            }
            Some((n as u64, n as u64))
        }
        LayerDesc::End => Some((0, 0)),
    }
}

fn check_config(cfg: &SocConfig, diags: &mut Vec<Diagnostic>) {
    for (name, v) in [
        ("cells", cfg.cells),
        ("ctrl_ram_words", cfg.ctrl_ram_words),
        ("dram_words", cfg.dram_words),
        ("spad_words", cfg.spad_words),
        ("spad_banks", cfg.spad_banks),
    ] {
        if v == 0 {
            diags.push(error(
                codes::DEGENERATE_GEOMETRY,
                None,
                format!("SoC config has {name} = 0 — no layer can execute"),
            ));
        }
    }
}

fn check_table_size(n_layers: usize, batch: u32, cfg: &SocConfig, diags: &mut Vec<Diagnostic>) {
    let need = (n_layers + 1) * DESC_WORDS;
    if need > cfg.ctrl_ram_words {
        diags.push(error(
            codes::TABLE_TOO_LARGE,
            None,
            format!(
                "{n_layers}-layer table needs {need} control-RAM words \
                 (incl. End), only {} available",
                cfg.ctrl_ram_words
            ),
        ));
    }
    if batch == 0 {
        diags.push(error(
            codes::TABLE_TOO_LARGE,
            None,
            "batch of 0 — the BATCH register needs at least 1".into(),
        ));
    }
    if batch > i32::MAX as u32 {
        diags.push(error(
            codes::TABLE_TOO_LARGE,
            None,
            format!("batch {batch} exceeds the BATCH register range (max {})", i32::MAX),
        ));
    }
}

/// Validate per-layer geometry with checked arithmetic and cross-check
/// Conv/Pool output shapes against the `cnn::layers` analytical model —
/// the two derivations must agree or the verifier flags the drift.
fn check_geometry(descs: &[LayerDesc], diags: &mut Vec<Diagnostic>) -> LayerLens {
    let mut lens = Vec::with_capacity(descs.len());
    for (i, d) in descs.iter().enumerate() {
        let l = layer_lens(d);
        match l {
            None => diags.push(error(
                codes::DEGENERATE_GEOMETRY,
                Some(i),
                format!("degenerate geometry: {d:?}"),
            )),
            Some((_, out)) => {
                let analytical = match *d {
                    LayerDesc::Conv {
                        cout,
                        cin,
                        k,
                        stride,
                        pad,
                        h,
                        w,
                        ..
                    } => Some(
                        Layer::Conv {
                            cout: cout as usize,
                            k: k as usize,
                            stride: stride as usize,
                            pad: pad as usize,
                        }
                        .out_shape(&LayerShape::Chw(cin as usize, h as usize, w as usize)),
                    ),
                    LayerDesc::Pool {
                        k,
                        stride,
                        kind,
                        c,
                        h,
                        w,
                        ..
                    } => Some(
                        Layer::Pool {
                            k: k as usize,
                            stride: stride as usize,
                            kind,
                        }
                        .out_shape(&LayerShape::Chw(c as usize, h as usize, w as usize)),
                    ),
                    _ => None,
                };
                match analytical {
                    Some(Err(e)) => diags.push(error(
                        codes::DEGENERATE_GEOMETRY,
                        Some(i),
                        format!("analytical shape model rejects the layer: {e}"),
                    )),
                    Some(Ok(shape)) if shape.volume() as u64 != out => diags.push(error(
                        codes::DEGENERATE_GEOMETRY,
                        Some(i),
                        format!(
                            "descriptor out_len {out} disagrees with the \
                             cnn::layers analytical volume {}",
                            shape.volume()
                        ),
                    )),
                    _ => {}
                }
            }
        }
        lens.push(l);
    }
    lens
}

#[derive(Clone, Copy)]
struct Region {
    addr: u64,
    len: u64,
}

impl Region {
    fn end(&self) -> u64 {
        self.addr + self.len
    }

    fn overlaps(&self, other: &Region) -> bool {
        self.len > 0 && other.len > 0 && self.addr < other.end() && other.addr < self.end()
    }

    fn same(&self, other: &Region) -> bool {
        self.addr == other.addr && self.len == other.len
    }
}

/// Weight regions of layer `i` as `(addr, len)` pairs, batch-independent.
fn weight_regions(d: &LayerDesc) -> Vec<Region> {
    d.weight_regions()
        .into_iter()
        .map(|(addr, len)| Region {
            addr: addr as u64,
            len: len as u64,
        })
        .collect()
}

/// Batch-scaled input/output activation regions of layer `i`.
fn activation_regions(d: &LayerDesc, lens: &Option<(u64, u64)>, batch: u64) -> Vec<Region> {
    let Some((in_len, out_len)) = *lens else {
        return Vec::new();
    };
    vec![
        Region {
            addr: d.in_addr() as u64,
            len: batch * in_len,
        },
        Region {
            addr: d.out_addr() as u64,
            len: batch * out_len,
        },
    ]
}

/// Check (a): every region in-bounds for the DRAM arena, and no layer's
/// weights overlap another live region. Activation↔activation overlap is
/// legal (chained tables alias by construction); read-only weights may
/// alias only when two layers share the *identical* region.
fn check_regions(
    descs: &[LayerDesc],
    lens: &LayerLens,
    batch: u32,
    cfg: &SocConfig,
    diags: &mut Vec<Diagnostic>,
) {
    let batch = batch.max(1) as u64;
    let dram = cfg.dram_words as u64;
    let mut weights: Vec<(usize, Region)> = Vec::new();
    let mut acts: Vec<(usize, &'static str, Region)> = Vec::new();
    for (i, d) in descs.iter().enumerate() {
        for r in weight_regions(d) {
            weights.push((i, r));
        }
        let a = activation_regions(d, &lens[i], batch);
        for (kind, r) in ["input", "output"].into_iter().zip(a) {
            acts.push((i, kind, r));
        }
    }
    for (i, r) in &weights {
        if r.end() > dram {
            diags.push(error(
                codes::REGION_OUT_OF_BOUNDS,
                Some(*i),
                format!(
                    "weight region [{}, {}) is out of bounds for the {dram}-word DRAM arena",
                    r.addr,
                    r.end()
                ),
            ));
        }
    }
    for (i, kind, r) in &acts {
        if r.end() > dram {
            diags.push(error(
                codes::REGION_OUT_OF_BOUNDS,
                Some(*i),
                format!(
                    "{kind} region [{}, {}) (batch {batch}) is out of bounds \
                     for the {dram}-word DRAM arena",
                    r.addr,
                    r.end()
                ),
            ));
        }
    }
    for (wi, (i, wr)) in weights.iter().enumerate() {
        for (j, kind, ar) in &acts {
            if wr.overlaps(ar) {
                diags.push(error(
                    codes::OVERLAPPING_DRAM_REGIONS,
                    Some(*i),
                    format!(
                        "weight region [{}, {}) overlaps layer {j}'s {kind} \
                         region [{}, {}) — activations would clobber weights",
                        wr.addr,
                        wr.end(),
                        ar.addr,
                        ar.end()
                    ),
                ));
            }
        }
        for (j, or) in weights.iter().skip(wi + 1) {
            if wr.overlaps(or) && !wr.same(or) {
                diags.push(error(
                    codes::OVERLAPPING_DRAM_REGIONS,
                    Some(*i),
                    format!(
                        "weight region [{}, {}) partially overlaps layer {j}'s \
                         weight region [{}, {})",
                        wr.addr,
                        wr.end(),
                        or.addr,
                        or.end()
                    ),
                ));
            }
        }
    }
}

/// Check (b): every consumer's input region must exactly match its
/// producer's output region — a partial overlap is corrupt dataflow
/// (Error), fully disjoint regions merely break the chain (Warn).
fn check_chain(descs: &[LayerDesc], lens: &LayerLens, batch: u32, diags: &mut Vec<Diagnostic>) {
    let batch = batch.max(1) as u64;
    if batch > 1 {
        for (i, d) in descs.iter().enumerate() {
            if matches!(d, LayerDesc::Fir { .. }) {
                diags.push(warn(
                    codes::FIR_IN_BATCHED_TABLE,
                    Some(i),
                    format!(
                        "FIR is a single-stream demo mode; batch {batch} runs \
                         it per-image with no amortization"
                    ),
                ));
            }
        }
    }
    for i in 0..descs.len().saturating_sub(1) {
        let (p, c) = (&descs[i], &descs[i + 1]);
        if matches!(p, LayerDesc::End) || matches!(c, LayerDesc::End) {
            continue;
        }
        let (Some((_, p_out)), Some((c_in, _))) = (lens[i], lens[i + 1]) else {
            continue; // degenerate geometry already reported
        };
        let pr = Region {
            addr: p.out_addr() as u64,
            len: batch * p_out,
        };
        let cr = Region {
            addr: c.in_addr() as u64,
            len: batch * c_in,
        };
        if pr.same(&cr) {
            continue;
        }
        if pr.overlaps(&cr) {
            diags.push(error(
                codes::BROKEN_DATAFLOW_CHAIN,
                Some(i + 1),
                format!(
                    "input region [{}, {}) intersects producer output \
                     [{}, {}) without matching it exactly",
                    cr.addr,
                    cr.end(),
                    pr.addr,
                    pr.end()
                ),
            ));
        } else {
            diags.push(warn(
                codes::UNCHAINED_LAYERS,
                Some(i + 1),
                format!(
                    "input region [{}, {}) is disjoint from producer output \
                     [{}, {}) — the layers do not chain",
                    cr.addr,
                    cr.end(),
                    pr.addr,
                    pr.end()
                ),
            ));
        }
    }
}

/// Check (c): fusion soundness. The residency budget and binding rules
/// are re-derived from first principles (`spad − 2 × staging banks`,
/// bindings disjoint and past the banks, resident footprints charged
/// together with both adjacent layers' cacheable weights) — NOT by
/// calling the planner.
pub fn verify_fusion(descs: &[LayerDesc], ctls: &[FusionCtl], cfg: &SocConfig) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    // mirror Scratchpad::bank_words: words / banks, floored, min 1
    let bank_words = (cfg.spad_words / cfg.spad_banks.max(1)).max(1);
    let staging = 2 * bank_words;
    let budget = cfg.spad_words.saturating_sub(staging);
    let cacheable = |d: &LayerDesc| -> usize {
        d.weight_regions()
            .iter()
            .map(|&(_, l)| l as usize)
            .filter(|&l| l <= budget)
            .sum()
    };
    for (i, ctl) in ctls.iter().enumerate() {
        if ctl.is_none() {
            continue;
        }
        let (b, r) = (ctl.spad_binding as usize, ctl.resident_words as usize);
        let Some(p) = descs.get(i) else { continue };
        let consumer = descs.get(i + 1);
        match consumer {
            None | Some(LayerDesc::End) => {
                diags.push(error(
                    codes::BROKEN_DATAFLOW_CHAIN,
                    Some(i),
                    "fuse_next is set on the last layer — there is no consumer".into(),
                ));
                continue;
            }
            Some(c) => {
                if p.out_addr() != c.in_addr()
                    || p.out_len() == 0
                    || layer_lens(p).is_none()
                    || layer_lens(c).is_none()
                    || p.out_len() != c.in_len()
                {
                    diags.push(error(
                        codes::BROKEN_DATAFLOW_CHAIN,
                        Some(i),
                        format!(
                            "fused edge over an unchained pair: producer out \
                             {}×{} vs consumer in {}×{}",
                            p.out_addr(),
                            p.out_len(),
                            c.in_addr(),
                            c.in_len()
                        ),
                    ));
                }
            }
        }
        if r == 0 {
            diags.push(error(
                codes::FUSION_BINDING_IN_STAGING_BANK,
                Some(i),
                "fused binding has a zero-word resident footprint".into(),
            ));
            continue;
        }
        if b < staging {
            diags.push(error(
                codes::FUSION_BINDING_IN_STAGING_BANK,
                Some(i),
                format!(
                    "resident binding [{b}, {}) intrudes into the DMA staging \
                     banks [0, {staging})",
                    b + r
                ),
            ));
        }
        if b + r > cfg.spad_words {
            diags.push(error(
                codes::FUSION_BINDING_IN_STAGING_BANK,
                Some(i),
                format!(
                    "resident binding [{b}, {}) extends past the {}-word scratchpad",
                    b + r,
                    cfg.spad_words
                ),
            ));
        }
        // adjacent live regions (layer i's input band and output band)
        let prev = (i > 0 && !ctls[i - 1].is_none()).then(|| {
            (
                ctls[i - 1].spad_binding as usize,
                ctls[i - 1].resident_words as usize,
            )
        });
        if let Some((pb, pr)) = prev {
            if pb < b + r && b < pb + pr {
                diags.push(error(
                    codes::FUSION_BINDING_OVERLAP,
                    Some(i),
                    format!(
                        "resident binding [{b}, {}) overlaps the live \
                         predecessor band [{pb}, {}) — both are resident \
                         while layer {i} computes",
                        b + r,
                        pb + pr
                    ),
                ));
            }
        }
        // the shared residency budget, re-derived: while the producer
        // computes, the predecessor band + this region + the producer's
        // cacheable weights share the arena; while the consumer drains
        // it, the region + the consumer's cacheable weights do
        let (prev_off, prev_words) = prev
            .map(|(pb, pr)| (pb.saturating_sub(staging), pr))
            .unwrap_or((0, 0));
        let off = b.saturating_sub(staging);
        let w_p = cacheable(p);
        let w_c = consumer.map(cacheable).unwrap_or(0);
        let high_water = (prev_off + prev_words).max(off + r);
        if high_water + w_p > budget {
            diags.push(error(
                codes::FUSION_BUDGET_EXCEEDED,
                Some(i),
                format!(
                    "producer-side footprint {high_water} + {w_p} cacheable \
                     weight words exceeds the {budget}-word residency budget",
                ),
            ));
        }
        if off + r + w_c > budget {
            diags.push(error(
                codes::FUSION_BUDGET_EXCEEDED,
                Some(i),
                format!(
                    "consumer-side footprint {} + {w_c} cacheable weight \
                     words exceeds the {budget}-word residency budget",
                    off + r
                ),
            ));
        }
    }
    diags
}

/// Check (d): the encoded ctrl-RAM image must round-trip — every block
/// re-encodes byte-identically from its descriptor + side-band, decodes
/// back to the same descriptor, carries a valid side-band version, and
/// the table ends in an `End` terminator block.
pub fn verify_image(descs: &[LayerDesc], ctls: &[FusionCtl], image: &[u32]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let need = (descs.len() + 1) * DESC_WORDS;
    if image.len() != need {
        diags.push(error(
            codes::ENCODING_MISMATCH,
            None,
            format!(
                "ctrl-RAM image is {} words, a {}-layer table encodes to {need}",
                image.len(),
                descs.len()
            ),
        ));
        return diags;
    }
    for (i, d) in descs.iter().enumerate() {
        let block = &image[i * DESC_WORDS..(i + 1) * DESC_WORDS];
        let ctl = ctls.get(i).copied().unwrap_or_default();
        // side-band version gate first: a block from a newer encoding
        // must not be diffed word-by-word as if we understood it
        if block[13] != 0 && block[13] >> 8 != FUSION_ENC_VERSION {
            diags.push(error(
                codes::BAD_FUSION_SIDEBAND_VERSION,
                Some(i),
                format!(
                    "fusion side-band version {} (this SoC speaks {FUSION_ENC_VERSION})",
                    block[13] >> 8
                ),
            ));
            continue;
        }
        let mut want = d.encode();
        ctl.encode_into(&mut want);
        if block != want {
            diags.push(error(
                codes::ENCODING_MISMATCH,
                Some(i),
                "ctrl-RAM block differs from the re-encoded descriptor + side-band".into(),
            ));
            continue;
        }
        match LayerDesc::decode(block) {
            Ok(back) if back == *d => {}
            Ok(_) => diags.push(error(
                codes::ENCODING_MISMATCH,
                Some(i),
                "descriptor encode→decode is not the identity".into(),
            )),
            Err(e) => diags.push(error(
                codes::ENCODING_MISMATCH,
                Some(i),
                format!("encoded block does not decode: {e}"),
            )),
        }
        match FusionCtl::decode(block) {
            Ok(back) if back == ctl => {}
            Ok(_) => diags.push(error(
                codes::ENCODING_MISMATCH,
                Some(i),
                "fusion side-band encode→decode is not the identity".into(),
            )),
            Err(e) => diags.push(error(
                codes::BAD_FUSION_SIDEBAND_VERSION,
                Some(i),
                e.to_string(),
            )),
        }
    }
    let end = &image[descs.len() * DESC_WORDS..];
    if end[0] != 0 {
        diags.push(error(
            codes::ENCODING_MISMATCH,
            None,
            format!("table is not End-terminated (opcode {} after the last layer)", end[0]),
        ));
    }
    diags
}

/// Check (e): per-layer static cycle lower bounds. Returns
/// `(compute, mem)` lower bounds per layer, saturating at `u64::MAX`.
///
/// Compute bounds mirror the engine's analytic models (conv row-FIR
/// passes, pool comparator waves) or divide MACs by the cell pool (FC);
/// memory bounds price each DRAM region at one burst
/// (`latency + ⌈words / words-per-cycle⌉`, the §III DRAM defaults) — a
/// true floor of both the serial and the staged DMA path, which only
/// split regions into *more* bursts.
pub fn cycle_lower_bounds(descs: &[LayerDesc], batch: u32, cfg: &SocConfig) -> Vec<(u64, u64)> {
    let sat = |v: u128| -> u64 { v.min(u64::MAX as u128) as u64 };
    descs
        .iter()
        .map(|d| {
            let Some(lens) = layer_lens(d) else {
                return (0, 0);
            };
            let (c, m) = cycle_lb(d, &lens, batch.max(1) as u64, cfg);
            (sat(c), sat(m))
        })
        .collect()
}

fn cycle_lb(d: &LayerDesc, lens: &(u64, u64), batch: u64, cfg: &SocConfig) -> (u128, u128) {
    // §III DRAM defaults (Dram::new): burst latency + streaming rate
    const BURST_LATENCY: u128 = 30;
    const WORDS_PER_CYCLE: u128 = 4;
    let cells = cfg.cells.max(1) as u128;
    let compute: u128 = match *d {
        LayerDesc::Conv {
            cout,
            cin,
            k,
            stride,
            pad,
            h: _,
            w,
            ..
        } => {
            let (cout, cin, k) = (cout as u128, cin as u128, k as u128);
            let wp = w as u128 + 2 * pad as u128;
            let ho = (lens.1 / cout as u64 / ((wp as u64 - k as u64) / stride as u64 + 1)) as u128;
            let lanes = (cells / k.max(1)).max(1);
            let row_passes = cout * cin * k * ho * batch as u128;
            let tap_sets = cout * cin * k;
            row_passes.div_ceil(lanes) * wp + tap_sets.div_ceil(lanes) * k
        }
        LayerDesc::Pool { k, .. } => {
            let windows = batch as u128 * lens.1 as u128;
            windows.div_ceil(cells) * (k as u128 * k as u128)
        }
        LayerDesc::Fc { n_in, n_out, .. } => {
            (batch as u128 * n_in as u128 * n_out as u128).div_ceil(cells).max(1)
        }
        LayerDesc::Fir { n, .. } => (n as u128).max(1),
        LayerDesc::End => 0,
    };
    let mut mem: u128 = 0;
    for (_, len) in d.weight_regions() {
        if len > 0 {
            mem += BURST_LATENCY + (len as u128).div_ceil(WORDS_PER_CYCLE);
        }
    }
    for len in [batch as u128 * lens.0 as u128, batch as u128 * lens.1 as u128] {
        if len > 0 {
            mem += BURST_LATENCY + len.div_ceil(WORDS_PER_CYCLE);
        }
    }
    (compute, mem)
}

/// Emit diagnostics when the static cycle model is inconsistent: a
/// non-`End` layer whose compute lower bound is zero (the overlap
/// invariant `overlapped ≤ min(compute, mem)` could then hide traffic
/// behind no work at all), or bounds that overflow `u64` (the SoC's
/// cycle counters would silently wrap).
fn check_cycles(
    descs: &[LayerDesc],
    lens: &LayerLens,
    batch: u32,
    cfg: &SocConfig,
    diags: &mut Vec<Diagnostic>,
) {
    let batch = batch.max(1) as u64;
    for (i, d) in descs.iter().enumerate() {
        if matches!(d, LayerDesc::End) {
            continue;
        }
        let Some(l) = lens[i] else { continue };
        let (compute, mem) = cycle_lb(d, &l, batch, cfg);
        if compute == 0 {
            diags.push(error(
                codes::DEGENERATE_GEOMETRY,
                Some(i),
                "static compute lower bound is 0 — the overlap invariant \
                 cannot be satisfied for a layer with no work"
                    .into(),
            ));
        }
        if compute > u64::MAX as u128 || mem > u64::MAX as u128 {
            diags.push(error(
                codes::DEGENERATE_GEOMETRY,
                Some(i),
                format!(
                    "static cycle bounds (compute {compute}, mem {mem}) \
                     overflow the SoC's 64-bit counters"
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::PoolKind;

    fn cfg() -> SocConfig {
        SocConfig {
            dram_words: 1 << 16,
            spad_words: 4096,
            ..Default::default()
        }
    }

    fn conv(in_addr: u32, out_addr: u32, w_addr: u32) -> LayerDesc {
        LayerDesc::Conv {
            cout: 4,
            cin: 1,
            k: 3,
            stride: 1,
            pad: 1,
            w_addr,
            in_addr,
            h: 8,
            w: 8,
            out_addr,
            relu: true,
            out_shift: 8,
        }
    }

    fn pool(in_addr: u32, out_addr: u32) -> LayerDesc {
        LayerDesc::Pool {
            k: 2,
            stride: 2,
            kind: PoolKind::Max,
            in_addr,
            c: 4,
            h: 8,
            w: 8,
            out_addr,
        }
    }

    #[test]
    fn clean_chained_table_verifies_clean() {
        // conv (64 in → 256 out) chains into pool (256 in → 64 out);
        // weights at 600 stay clear of the batch-8 input region [0, 512)
        let descs = vec![conv(0, 1000, 600), pool(1000, 2000)];
        for batch in [1u32, 8] {
            let diags = verify_table(&descs, batch, &cfg());
            assert!(diags.is_empty(), "batch {batch}: {diags:?}");
        }
    }

    #[test]
    fn degenerate_pool_is_flagged_not_panicking() {
        // h < k would underflow-wrap LayerDesc::out_len in release builds;
        // the verifier must report E012 without ever computing it
        let d = LayerDesc::Pool {
            k: 5,
            stride: 1,
            kind: PoolKind::Max,
            in_addr: 0,
            c: 1,
            h: 3,
            w: 3,
            out_addr: 100,
        };
        let diags = verify_table(&[d], 1, &cfg());
        assert!(diags.iter().any(|d| d.code == codes::DEGENERATE_GEOMETRY), "{diags:?}");
        // zero stride divides in out_len — same guard
        let d = LayerDesc::Conv {
            cout: 1,
            cin: 1,
            k: 3,
            stride: 0,
            pad: 0,
            w_addr: 0,
            in_addr: 0,
            h: 8,
            w: 8,
            out_addr: 100,
            relu: false,
            out_shift: 0,
        };
        let diags = verify_table(&[d], 1, &cfg());
        assert!(diags.iter().any(|d| d.code == codes::DEGENERATE_GEOMETRY), "{diags:?}");
    }

    #[test]
    fn weight_overlap_and_oob_are_errors() {
        // conv weights at 1010 land inside the conv's own output [1000,
        // 1256) — activations would clobber weights
        let descs = vec![conv(0, 1000, 1010)];
        let diags = verify_table(&descs, 1, &cfg());
        assert!(diags.iter().any(|d| d.code == codes::OVERLAPPING_DRAM_REGIONS), "{diags:?}");
        // a weight region past the arena end
        let descs = vec![conv(0, 1000, (1 << 16) - 2)];
        let diags = verify_table(&descs, 1, &cfg());
        assert!(diags.iter().any(|d| d.code == codes::REGION_OUT_OF_BOUNDS), "{diags:?}");
    }

    #[test]
    fn chain_mismatch_severity_split() {
        // intersecting but not identical: Error
        let descs = vec![conv(0, 1000, 100), pool(1004, 2000)];
        let diags = verify_table(&descs, 1, &cfg());
        assert!(diags.iter().any(|d| d.code == codes::BROKEN_DATAFLOW_CHAIN), "{diags:?}");
        // fully disjoint: Warn only
        let descs = vec![conv(0, 1000, 100), pool(3000, 4000)];
        let diags = verify_table(&descs, 1, &cfg());
        assert!(!has_errors(&diags), "{diags:?}");
        assert!(diags.iter().any(|d| d.code == codes::UNCHAINED_LAYERS), "{diags:?}");
    }

    #[test]
    fn image_roundtrip_catches_corruption() {
        let descs = vec![conv(0, 1000, 100)];
        let ctls = vec![FusionCtl::none()];
        let mut image = Vec::new();
        for d in &descs {
            image.extend_from_slice(&d.encode());
        }
        image.extend_from_slice(&LayerDesc::End.encode());
        assert!(verify_image(&descs, &ctls, &image).is_empty());
        // corrupt one geometry word
        let mut bad = image.clone();
        bad[3] += 1;
        let diags = verify_image(&descs, &ctls, &bad);
        assert!(diags.iter().any(|d| d.code == codes::ENCODING_MISMATCH), "{diags:?}");
        // clobber the End terminator
        let mut bad = image.clone();
        bad[DESC_WORDS] = 4;
        let diags = verify_image(&descs, &ctls, &bad);
        assert!(diags.iter().any(|d| d.code == codes::ENCODING_MISMATCH), "{diags:?}");
    }

    #[test]
    fn cycle_lower_bounds_are_positive_and_monotone_in_batch() {
        let descs = vec![conv(0, 1000, 100), pool(1000, 2000)];
        let b1 = cycle_lower_bounds(&descs, 1, &cfg());
        let b8 = cycle_lower_bounds(&descs, 8, &cfg());
        for i in 0..descs.len() {
            assert!(b1[i].0 > 0 && b1[i].1 > 0, "layer {i}: {b1:?}");
            assert!(b8[i].0 >= b1[i].0 && b8[i].1 >= b1[i].1, "layer {i}");
        }
    }

    #[test]
    fn fir_in_batched_table_is_warn_only() {
        let d = LayerDesc::Fir {
            taps_addr: 0,
            n_taps: 2,
            in_addr: 2,
            n: 4,
            out_addr: 6,
        };
        let diags = verify_table(&[d], 2, &cfg());
        assert!(!has_errors(&diags), "{diags:?}");
        assert!(diags.iter().any(|d| d.code == codes::FIR_IN_BATCHED_TABLE), "{diags:?}");
        assert!(verify_table(&[d], 1, &cfg()).is_empty());
    }
}
