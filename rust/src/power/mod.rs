//! Activity-based power estimation — Table 5's power column.
//!
//! Methodology mirrors Xilinx XPE: dynamic power = Σ (toggle rate × C_eff ×
//! V² × f) over LUT and FF outputs, plus a leakage floor proportional to
//! occupied slices. Toggle rates come from cycle-accurate simulation of the
//! mapped netlist under uniform-random stimulus (the standard sign-off
//! assumption when no application trace exists).

use crate::bits::BitVec;
use crate::error::Result;
use crate::sim::CycleSim;
use crate::techmap::MappedNetlist;

/// Electrical constants for the power model.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Core voltage (V).
    pub vdd: f64,
    /// Effective switched capacitance per LUT output incl. routing (F).
    pub c_lut: f64,
    /// Effective switched capacitance per FF output (F).
    pub c_ff: f64,
    /// Static leakage per occupied slice (W).
    pub leak_per_slice: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            vdd: 1.0,
            c_lut: 1.1e-12,
            c_ff: 0.4e-12,
            leak_per_slice: 1.5e-6,
        }
    }
}

/// Power estimate breakdown.
#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    /// Dynamic power in watts at `freq_hz`.
    pub dynamic_w: f64,
    /// Static (leakage) power in watts.
    pub static_w: f64,
    /// Clock frequency used.
    pub freq_hz: f64,
    /// Mean toggle rate over LUT outputs (α, toggles per cycle).
    pub mean_activity: f64,
}

impl PowerReport {
    /// Total power in milliwatts.
    pub fn total_mw(&self) -> f64 {
        (self.dynamic_w + self.static_w) * 1e3
    }
}

/// Estimate power of a mapped netlist at `freq_hz` by simulating `cycles`
/// uniform-random input vectors.
pub fn estimate(mapped: &MappedNetlist, freq_hz: f64, cycles: usize) -> Result<PowerReport> {
    estimate_with(mapped, freq_hz, cycles, &PowerModel::default(), 0x1234_5678)
}

/// Estimate with explicit model and RNG seed (for reproducibility tests).
pub fn estimate_with(
    mapped: &MappedNetlist,
    freq_hz: f64,
    cycles: usize,
    pm: &PowerModel,
    seed: u64,
) -> Result<PowerReport> {
    let nl = &mapped.netlist;
    let mut sim = CycleSim::new(nl)?;
    sim.enable_activity();
    sim.reset();

    let mut state = seed.max(1);
    let mut rnd = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };

    let inputs: Vec<(String, Vec<crate::netlist::NetId>)> = nl
        .inputs()
        .iter()
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    for _ in 0..cycles {
        for (_, bus) in &inputs {
            let mut v = BitVec::zeros(bus.len());
            for i in 0..bus.len() {
                v.set(i, rnd() & 1 == 1);
            }
            sim.set_bus(bus, &v);
        }
        sim.settle();
        sim.step_clock();
    }
    let act = sim.activity()?;

    let mut dynamic = 0f64;
    let mut lut_act_sum = 0f64;
    let mut lut_count = 0usize;
    for (id, d) in nl.iter() {
        let a = act[id.index()];
        match d {
            crate::netlist::Driver::Gate(g) if g.is_dff() => {
                dynamic += a * pm.c_ff * pm.vdd * pm.vdd * freq_hz;
            }
            crate::netlist::Driver::Gate(_) if mapped.mapping.is_lut_root(id) => {
                dynamic += a * pm.c_lut * pm.vdd * pm.vdd * freq_hz;
                lut_act_sum += a;
                lut_count += 1;
            }
            _ => {}
        }
    }
    Ok(PowerReport {
        dynamic_w: dynamic,
        static_w: mapped.report.slices as f64 * pm.leak_per_slice,
        freq_hz,
        mean_activity: if lut_count > 0 { lut_act_sum / lut_count as f64 } else { 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::{generate, MultKind, MultiplierSpec};
    use crate::techmap;

    #[test]
    fn bigger_multiplier_burns_more() {
        let p = |w| {
            let m = generate(MultiplierSpec::comb(MultKind::Dadda, w)).unwrap();
            let mapped = techmap::map(&m.netlist).unwrap();
            estimate(&mapped, 100e6, 200).unwrap().total_mw()
        };
        let p8 = p(8);
        let p32 = p(32);
        assert!(p32 > 4.0 * p8, "p8={p8:.3}mW p32={p32:.3}mW");
    }

    #[test]
    fn deterministic_given_seed() {
        let m = generate(MultiplierSpec::comb(MultKind::Dadda, 8)).unwrap();
        let mapped = techmap::map(&m.netlist).unwrap();
        let a = estimate_with(&mapped, 100e6, 100, &PowerModel::default(), 7).unwrap();
        let b = estimate_with(&mapped, 100e6, 100, &PowerModel::default(), 7).unwrap();
        assert_eq!(a.total_mw(), b.total_mw());
    }

    #[test]
    fn kom32_lands_in_tens_of_milliwatts_at_fmax() {
        // Table 5 magnitude check: paper reports 90.37 mW for the 32-bit
        // pipelined KOM; our model should land within the same decade.
        let m = generate(MultiplierSpec::pipelined(MultKind::KaratsubaOfman, 32, 6)).unwrap();
        let mapped = techmap::map(&m.netlist).unwrap();
        let t = crate::sta::analyze(&mapped);
        let f = t.fmax_mhz.unwrap() * 1e6;
        let p = estimate(&mapped, f, 150).unwrap().total_mw();
        assert!(p > 9.0 && p < 900.0, "p={p:.1}mW at fmax={:.0}MHz", f / 1e6);
    }
}
