//! End-to-end serving bench: throughput/latency across worker counts and
//! batch policies, the simulated batched-vs-sequential accelerator
//! speedup (measured, not asserted), plus the XLA-artifact execution path
//! (when built).

use kom_accel::accel::{Driver, FaultConfig, FaultPlan, SocConfig, DEFAULT_RING_CAPACITY};
use kom_accel::cluster::{Cluster, ClusterConfig, SchedulePolicy, Scheduler};
use kom_accel::cnn::networks::{Network, NetworkInstance, NetworkKind, DEFAULT_SHARD_RETRIES};
use kom_accel::cnn::Tensor;
use kom_accel::coordinator::{
    probe_us_per_req, run_loadgen, Arrivals, BatchMode, BatchPolicy, Coordinator,
    CoordinatorConfig, LoadGenConfig, LoadGenReport,
};
use kom_accel::report::Table;
use kom_accel::runtime::{golden, ArtifactStore, Runtime};
use std::path::Path;
use std::time::{Duration, Instant};

fn bench_soc() -> SocConfig {
    SocConfig::serving()
}

fn main() {
    println!("\n===== E2E serving bench (Tiny CNN) =====");
    let inst = NetworkInstance::random(Network::build(NetworkKind::Tiny), 42).unwrap();
    let n_requests = 128;
    let inputs: Vec<Tensor> = (0..n_requests)
        .map(|i| Tensor::random(vec![1, 16, 16], 127, i as u64 + 1))
        .collect();

    let mut t = Table::new(&[
        "workers",
        "max batch",
        "wall (ms)",
        "req/s",
        "p50 (us)",
        "p99 (us)",
        "mean batch",
        "accel cycles/req",
    ]);
    for workers in [1usize, 2, 4] {
        for max_batch in [1usize, 8] {
            let coord = Coordinator::start(
                CoordinatorConfig {
                    workers,
                    batch: BatchPolicy {
                        max_batch,
                        max_wait: Duration::from_micros(500),
                    },
                    ..Default::default()
                },
                &inst,
            )
            .unwrap();
            let t0 = Instant::now();
            let rxs: Vec<_> = inputs
                .iter()
                .map(|img| coord.submit(img.clone()).unwrap())
                .collect();
            for (_, rx) in rxs {
                rx.recv().unwrap();
            }
            let wall = t0.elapsed();
            let stats = coord.shutdown();
            let lat = stats.latency();
            t.row(vec![
                workers.to_string(),
                max_batch.to_string(),
                format!("{:.1}", wall.as_secs_f64() * 1e3),
                format!("{:.0}", n_requests as f64 / wall.as_secs_f64()),
                lat.p50_us.to_string(),
                lat.p99_us.to_string(),
                format!("{:.1}", stats.mean_batch()),
                format!("{:.0}", stats.amortized_cycles_per_request()),
            ]);
        }
    }
    println!("{}", t.to_ascii());

    // ---- simulated accelerator: batched vs sequential -----------------
    // The honest comparison: same simulator, same weights, same inputs.
    // Sequential = one run_table per request; batched = one
    // run_table_batch per batch. The gap is the amortized control program,
    // engine reconfiguration (weight words), FIR tap reloads, and DRAM
    // burst latency.
    println!("===== batched vs sequential (simulated accelerator cycles) =====");
    let mut t = Table::new(&[
        "batch",
        "seq cycles/req",
        "batched cycles/req",
        "speedup",
    ]);
    let probe: Vec<Tensor> = inputs.iter().take(32).cloned().collect();
    let mut seq_drv = Driver::new(bench_soc());
    let (descs, in_addr, _) = inst.deploy(&mut seq_drv).unwrap();
    let mut seq_cycles = 0u64;
    for img in &probe {
        seq_drv.write_region(in_addr, &img.data).unwrap();
        seq_cycles += seq_drv.run_table(&descs).unwrap().total_cycles();
    }
    let seq_per_req = seq_cycles as f64 / probe.len() as f64;
    for batch in [2usize, 4, 8, 16] {
        let mut drv = Driver::new(bench_soc());
        let dep = inst.deploy_batched(&mut drv, batch).unwrap();
        let mut cycles = 0u64;
        for chunk in probe.chunks(batch) {
            let mut packed = Vec::with_capacity(chunk.len() * dep.in_len);
            for img in chunk {
                packed.extend_from_slice(&img.data);
            }
            drv.write_region(dep.in_addr, &packed).unwrap();
            cycles += dep.run(&mut drv, chunk.len() as u32).unwrap().total_cycles();
        }
        let per_req = cycles as f64 / probe.len() as f64;
        t.row(vec![
            batch.to_string(),
            format!("{seq_per_req:.0}"),
            format!("{per_req:.0}"),
            format!("{:.2}x", seq_per_req / per_req),
        ]);
    }
    println!("{}", t.to_ascii());

    // ---- sharded scale-out: shards × batch (simulated cluster cycles) --
    // One batch split data-parallel across replicated SoCs; the cluster
    // cost is the max over shards (replicas run concurrently), so the
    // speedup column is the scale-out claim of the cluster subsystem.
    println!("===== sharded scale-out: shards x batch (simulated cluster cycles/req) =====");
    let batches = [4usize, 8, 16];
    let mut t = Table::new(&["shards", "batch 4", "batch 8", "batch 16", "speedup @16"]);
    let mut one_shard_at_16 = 0u64;
    for shards in [1usize, 2, 4] {
        let mut cells = Vec::new();
        let mut at_16 = 0u64;
        for &batch in &batches {
            let mut cluster = Cluster::new(ClusterConfig {
                replicas: shards,
                soc: bench_soc(),
            })
            .unwrap();
            let cdep = inst
                .deploy_cluster(&mut cluster, batch.div_ceil(shards))
                .unwrap();
            let mut sched =
                Scheduler::new(SchedulePolicy::LeastOutstandingCycles, shards).unwrap();
            let slices: Vec<&[i64]> = inputs[..batch].iter().map(|t| t.data.as_slice()).collect();
            cdep.run_sharded(&mut cluster, &mut sched, &slices).unwrap(); // warm
            let (_, m) = cdep.run_sharded(&mut cluster, &mut sched, &slices).unwrap();
            let cycles = m.total_cycles();
            if batch == 16 {
                at_16 = cycles;
            }
            cells.push(format!("{:.0}", cycles as f64 / batch as f64));
        }
        if shards == 1 {
            one_shard_at_16 = at_16;
        }
        let speedup = format!("{:.2}x", one_shard_at_16 as f64 / at_16 as f64);
        t.row(
            std::iter::once(shards.to_string())
                .chain(cells)
                .chain(std::iter::once(speedup))
                .collect(),
        );
    }
    println!("{}", t.to_ascii());

    // ---- pipelined vs serial: layer DMA overlapped with compute --------
    // Same simulator, same weights, same inputs; the only difference is
    // the SoC PIPELINE register. Serial charges cpu + compute + mem;
    // pipelined charges cpu + compute + (mem − overlapped). Emitted as
    // BENCH_pipeline.json so CI tracks the perf trajectory.
    println!("===== pipelined vs serial (simulated cluster cycles/req, batch 8) =====");
    let pipe_batch = 8usize;
    let mut t = Table::new(&[
        "shards",
        "serial cycles/req",
        "pipelined cycles/req",
        "overlapped",
        "speedup",
    ]);
    let mut json_rows = Vec::new();
    for shards in [1usize, 2, 4] {
        let slices: Vec<&[i64]> = inputs[..pipe_batch].iter().map(|t| t.data.as_slice()).collect();
        let mut totals = [0u64; 2];
        let mut overlapped = 0u64;
        for (i, pipeline) in [false, true].into_iter().enumerate() {
            let mut cluster = Cluster::new(ClusterConfig {
                replicas: shards,
                soc: bench_soc(),
            })
            .unwrap();
            cluster.set_pipeline(pipeline).unwrap();
            let cdep = inst
                .deploy_cluster(&mut cluster, pipe_batch.div_ceil(shards))
                .unwrap();
            let mut sched =
                Scheduler::new(SchedulePolicy::LeastOutstandingCycles, shards).unwrap();
            let (_, m) = cdep.run_sharded(&mut cluster, &mut sched, &slices).unwrap();
            totals[i] = m.total_cycles();
            if pipeline {
                overlapped = m.overlapped_cycles();
            }
        }
        let serial_per = totals[0] as f64 / pipe_batch as f64;
        let piped_per = totals[1] as f64 / pipe_batch as f64;
        let speedup = totals[0] as f64 / totals[1] as f64;
        t.row(vec![
            shards.to_string(),
            format!("{serial_per:.0}"),
            format!("{piped_per:.0}"),
            overlapped.to_string(),
            format!("{speedup:.2}x"),
        ]);
        json_rows.push(format!(
            "    {{\"shards\": {shards}, \"batch\": {pipe_batch}, \
             \"serial_cycles_per_req\": {serial_per:.1}, \
             \"pipelined_cycles_per_req\": {piped_per:.1}, \
             \"overlapped_cycles\": {overlapped}, \
             \"speedup\": {speedup:.4}}}"
        ));
    }
    println!("{}", t.to_ascii());
    let json = format!(
        "{{\n  \"bench\": \"pipeline\",\n  \"network\": \"tiny\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write("BENCH_pipeline.json", &json) {
        Ok(()) => println!("wrote BENCH_pipeline.json (cycles/req, serial vs pipelined x shards)"),
        Err(e) => println!("(could not write BENCH_pipeline.json: {e})"),
    }

    // ---- fused vs pipelined vs serial: the DRAM round trip eliminated --
    // Fusion keeps chained layers' intermediates scratchpad-resident, so
    // their store+reload is skipped outright (pipelining could only hide
    // it under compute). Same simulator, weights and inputs; the columns
    // differ only in the PIPELINE register / fusion planner settings.
    // Emitted as BENCH_fusion.json — including the serial baseline so the
    // perf trajectory is self-describing.
    println!("===== fused x pipelined x shards (simulated cluster cycles/req, batch 8) =====");
    let mut t = Table::new(&[
        "shards",
        "serial",
        "pipelined",
        "fused+pipelined",
        "fused-saved",
        "vs serial",
        "vs pipelined",
    ]);
    let mut json_rows = Vec::new();
    for shards in [1usize, 2, 4] {
        let slices: Vec<&[i64]> = inputs[..pipe_batch].iter().map(|t| t.data.as_slice()).collect();
        // (pipeline, fuse): serial, pipelined-only, fused+pipelined
        let mut totals = [0u64; 3];
        let mut fused_saved = 0u64;
        for (i, (pipeline, fuse)) in [(false, false), (true, false), (true, true)]
            .into_iter()
            .enumerate()
        {
            let mut cluster = Cluster::new(ClusterConfig {
                replicas: shards,
                soc: bench_soc(),
            })
            .unwrap();
            cluster.set_pipeline(pipeline).unwrap();
            cluster.set_fusion(fuse);
            let cdep = inst
                .deploy_cluster(&mut cluster, pipe_batch.div_ceil(shards))
                .unwrap();
            let mut sched =
                Scheduler::new(SchedulePolicy::LeastOutstandingCycles, shards).unwrap();
            let (_, m) = cdep.run_sharded(&mut cluster, &mut sched, &slices).unwrap();
            totals[i] = m.total_cycles();
            if fuse {
                fused_saved = m.fused_saved_cycles();
            }
        }
        let per = |c: u64| c as f64 / pipe_batch as f64;
        let vs_serial = totals[0] as f64 / totals[2] as f64;
        let vs_pipelined = totals[1] as f64 / totals[2] as f64;
        t.row(vec![
            shards.to_string(),
            format!("{:.0}", per(totals[0])),
            format!("{:.0}", per(totals[1])),
            format!("{:.0}", per(totals[2])),
            fused_saved.to_string(),
            format!("{vs_serial:.2}x"),
            format!("{vs_pipelined:.2}x"),
        ]);
        json_rows.push(format!(
            "    {{\"shards\": {shards}, \"batch\": {pipe_batch}, \
             \"serial_cycles_per_req\": {:.1}, \
             \"pipelined_cycles_per_req\": {:.1}, \
             \"fused_pipelined_cycles_per_req\": {:.1}, \
             \"fused_saved_cycles\": {fused_saved}, \
             \"speedup_vs_serial\": {vs_serial:.4}, \
             \"speedup_vs_pipelined\": {vs_pipelined:.4}}}",
            per(totals[0]),
            per(totals[1]),
            per(totals[2]),
        ));
    }
    println!("{}", t.to_ascii());
    let json = format!(
        "{{\n  \"bench\": \"fusion\",\n  \"network\": \"tiny\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write("BENCH_fusion.json", &json) {
        Ok(()) => println!("wrote BENCH_fusion.json (cycles/req, fused x pipelined x shards)"),
        Err(e) => println!("(could not write BENCH_fusion.json: {e})"),
    }

    // ---- compiled plans: cold vs warm execution ------------------------
    // The cold dispatch compiles the execution plan (fusion planning,
    // descriptor encoding, control program) and loads the engine's
    // configuration contexts; warm dispatches execute the cached plan and
    // skip every per-layer reconfiguration. Fused + pipelined + config
    // cache on — the full serving configuration. Emitted as
    // BENCH_plan_cache.json so CI tracks the warm-path trajectory.
    println!("===== compiled plans: cold vs warm (simulated cluster cycles/req, batch 16) =====");
    let plan_batch = 16usize;
    let mut t = Table::new(&[
        "shards",
        "cold cycles/req",
        "warm cycles/req",
        "warm speedup",
        "reconf skipped",
        "plan hit rate",
    ]);
    let mut json_rows = Vec::new();
    for shards in [1usize, 2, 4] {
        let slices: Vec<&[i64]> = inputs[..plan_batch].iter().map(|t| t.data.as_slice()).collect();
        let mut cluster = Cluster::new(ClusterConfig {
            replicas: shards,
            soc: bench_soc(),
        })
        .unwrap();
        cluster.set_pipeline(true).unwrap();
        cluster.set_fusion(true);
        cluster.set_config_cache(true);
        let cdep = inst
            .deploy_cluster(&mut cluster, plan_batch.div_ceil(shards))
            .unwrap();
        let mut sched = Scheduler::new(SchedulePolicy::LeastOutstandingCycles, shards).unwrap();
        let (_, cold) = cdep.run_sharded(&mut cluster, &mut sched, &slices).unwrap();
        let (_, warm) = cdep.run_sharded(&mut cluster, &mut sched, &slices).unwrap();
        let cold_per = cold.total_cycles() as f64 / plan_batch as f64;
        let warm_per = warm.total_cycles() as f64 / plan_batch as f64;
        let speedup = cold.total_cycles() as f64 / warm.total_cycles().max(1) as f64;
        let skipped = warm.reconfigs_skipped();
        let (hits, compiles) = cluster.plan_cache_stats();
        let hit_rate = hits as f64 / (hits + compiles).max(1) as f64;
        t.row(vec![
            shards.to_string(),
            format!("{cold_per:.0}"),
            format!("{warm_per:.0}"),
            format!("{speedup:.2}x"),
            skipped.to_string(),
            format!("{:.0}%", hit_rate * 100.0),
        ]);
        json_rows.push(format!(
            "    {{\"shards\": {shards}, \"batch\": {plan_batch}, \
             \"cold_cycles_per_req\": {cold_per:.1}, \
             \"warm_cycles_per_req\": {warm_per:.1}, \
             \"warm_speedup\": {speedup:.4}, \
             \"warm_reconfigs_skipped\": {skipped}, \
             \"plan_cache_hit_rate\": {hit_rate:.4}}}"
        ));
    }
    println!("{}", t.to_ascii());
    let json = format!(
        "{{\n  \"bench\": \"plan_cache\",\n  \"network\": \"tiny\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write("BENCH_plan_cache.json", &json) {
        Ok(()) => println!("wrote BENCH_plan_cache.json (cold vs warm compiled-plan execution)"),
        Err(e) => println!("(could not write BENCH_plan_cache.json: {e})"),
    }

    // ---- execution tracing: traced vs untraced overhead ----------------
    // The tracer's contract is that it is the cycle model's ledger, not a
    // participant: armed or not, the simulated cycle counts are identical
    // (hard-asserted here — the gate CI runs), and when armed the ring
    // bounds host memory to its capacity. Wall-clock cost is measured on
    // warm fused+pipelined batch-8 runs and emitted as
    // BENCH_trace_overhead.json so CI tracks the host-side overhead too.
    println!("===== execution tracing: traced vs untraced (warm batch 8, fused+pipelined) =====");
    let trace_iters = 20u32;
    let trace_batch = 8usize;
    let measure = |traced: bool| -> (f64, u64, usize) {
        let mut drv = Driver::new(bench_soc());
        drv.set_pipeline(true).unwrap();
        drv.set_fusion(true);
        drv.set_config_cache(true);
        if traced {
            drv.set_tracing(DEFAULT_RING_CAPACITY);
        }
        let dep = inst.deploy_batched(&mut drv, trace_batch).unwrap();
        let mut packed = Vec::with_capacity(trace_batch * dep.in_len);
        for img in inputs.iter().take(trace_batch) {
            packed.extend_from_slice(&img.data);
        }
        drv.write_region(dep.in_addr, &packed).unwrap();
        dep.run(&mut drv, trace_batch as u32).unwrap(); // warm the plan + weights
        let _ = drv.take_trace();
        let mut cycles = 0u64;
        let mut max_spans = 0usize;
        let t0 = Instant::now();
        for _ in 0..trace_iters {
            cycles += dep.run(&mut drv, trace_batch as u32).unwrap().total_cycles();
            if let Some(tr) = drv.take_trace() {
                max_spans = max_spans.max(tr.events.len());
            }
        }
        (t0.elapsed().as_secs_f64() * 1e3, cycles, max_spans)
    };
    let (wall_off, cycles_off, spans_off) = measure(false);
    let (wall_on, cycles_on, spans_on) = measure(true);
    // the gates: tracing never perturbs the simulated cycle model, the
    // disabled tracer emits nothing, and the armed ring stays bounded
    assert_eq!(
        cycles_off, cycles_on,
        "tracing must cost zero simulated cycles (off: {cycles_off}, on: {cycles_on})"
    );
    assert_eq!(spans_off, 0, "disabled tracer must emit nothing");
    assert!(
        spans_on > 0 && spans_on <= DEFAULT_RING_CAPACITY,
        "armed tracer must record within its ring capacity (got {spans_on})"
    );
    let overhead_pct = (wall_on - wall_off) / wall_off.max(1e-9) * 100.0;
    let mut t = Table::new(&[
        "tracing",
        "wall (ms)",
        "sim cycles/req",
        "max spans/run",
        "wall overhead",
    ]);
    let per_req = |c: u64| c as f64 / (trace_iters as usize * trace_batch) as f64;
    t.row(vec![
        "off".into(),
        format!("{wall_off:.2}"),
        format!("{:.0}", per_req(cycles_off)),
        "0".into(),
        "baseline".into(),
    ]);
    t.row(vec![
        "on".into(),
        format!("{wall_on:.2}"),
        format!("{:.0}", per_req(cycles_on)),
        spans_on.to_string(),
        format!("{overhead_pct:+.1}%"),
    ]);
    println!("{}", t.to_ascii());
    println!("gate: simulated cycles identical traced vs untraced (0 extra) — OK");
    let json = format!(
        "{{\n  \"bench\": \"trace_overhead\",\n  \"network\": \"tiny\",\n  \"rows\": [\n    \
         {{\"iters\": {trace_iters}, \"batch\": {trace_batch}, \
         \"untraced_wall_ms\": {wall_off:.3}, \"traced_wall_ms\": {wall_on:.3}, \
         \"wall_overhead_pct\": {overhead_pct:.2}, \
         \"sim_cycles_per_req\": {:.1}, \
         \"extra_sim_cycles_traced\": 0, \
         \"max_spans_per_run\": {spans_on}, \"ring_capacity\": {DEFAULT_RING_CAPACITY}}}\n  ]\n}}\n",
        per_req(cycles_on)
    );
    match std::fs::write("BENCH_trace_overhead.json", &json) {
        Ok(()) => println!("wrote BENCH_trace_overhead.json (traced vs untraced serving overhead)"),
        Err(e) => println!("(could not write BENCH_trace_overhead.json: {e})"),
    }

    // ---- unified cache hierarchy: per-cache hit rates under serving ----
    // One warm composed scenario through the coordinator (fused +
    // pipelined + config cache + dedup, 2 replicas): distinct inputs to
    // warm every cache, then exact repeats to exercise the front door.
    // The per-replica weight/context/plan rows and the shared dedup row
    // are the same snapshots `Coordinator::metrics_text` scrapes as
    // kom_cache_*. Gates: warm dedup, plan and context caches all hit,
    // and Tiny's working set never pressures the weight cache (0
    // evictions). Emitted as BENCH_cache_stats.json.
    println!("===== unified cache hierarchy (warm serving, 2 shards, batch 8) =====");
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            shards: 2,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
            },
            ..Default::default()
        },
        &inst,
    )
    .unwrap();
    // two rounds of the same 32 inputs: round one warms every cache and
    // completes before round two begins, so every second-round submit is
    // a guaranteed front-door dedup hit
    for _ in 0..2 {
        let rxs: Vec<_> = inputs
            .iter()
            .take(32)
            .map(|img| coord.submit(img.clone()).unwrap())
            .collect();
        for (_, rx) in rxs {
            rx.recv().unwrap();
        }
    }
    let cache_stats = coord.shutdown();
    let dedup_row = cache_stats
        .dedup_cache_stats()
        .expect("dedup enabled by default");
    let hit_rate = |h: u64, m: u64| h as f64 / (h + m).max(1) as f64;
    let mut t = Table::new(&[
        "cache",
        "worker",
        "replica",
        "hits",
        "misses",
        "evictions",
        "resident words",
        "hit rate",
    ]);
    let mut json_rows = Vec::new();
    let mut row = |name: &str, w: String, r: String, s: kom_accel::cache::CacheStats| {
        t.row(vec![
            name.into(),
            w.clone(),
            r.clone(),
            s.hits.to_string(),
            s.misses.to_string(),
            s.evictions.to_string(),
            s.resident_cost.to_string(),
            format!("{:.0}%", hit_rate(s.hits, s.misses) * 100.0),
        ]);
        json_rows.push(format!(
            "    {{\"cache\": \"{name}\", \"worker\": \"{w}\", \"replica\": \"{r}\", \
             \"hits\": {}, \"misses\": {}, \"evictions\": {}, \"resident_words\": {}, \
             \"hit_rate\": {:.4}}}",
            s.hits,
            s.misses,
            s.evictions,
            s.resident_cost,
            hit_rate(s.hits, s.misses)
        ));
    };
    let mut weight_evictions = 0u64;
    let mut plan_hits = 0u64;
    let mut ctx_hits = 0u64;
    for &(w, r, d) in cache_stats.cache_rows() {
        row("weight", w.to_string(), r.to_string(), d.weight);
        row("context", w.to_string(), r.to_string(), d.context);
        row("plan", w.to_string(), r.to_string(), d.plan);
        weight_evictions += d.weight.evictions;
        plan_hits += d.plan.hits;
        ctx_hits += d.context.hits;
    }
    row("dedup", "-".into(), "-".into(), dedup_row);
    drop(row);
    println!("{}", t.to_ascii());
    // the gates CI relies on: warm serving must hit every cache tier,
    // and Tiny must never evict resident weights
    assert!(dedup_row.hits > 0, "exact repeats must hit the front door");
    assert!(plan_hits > 0, "warm batches must execute cached plans");
    assert!(ctx_hits > 0, "warm runs must hit resident engine contexts");
    assert_eq!(
        weight_evictions, 0,
        "Tiny's weights fit the scratchpad budget: no evictions expected"
    );
    println!(
        "gates: dedup hits {} / plan hits {plan_hits} / context hits {ctx_hits} / \
         weight evictions {weight_evictions} — OK",
        dedup_row.hits
    );
    let json = format!(
        "{{\n  \"bench\": \"cache_stats\",\n  \"network\": \"tiny\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write("BENCH_cache_stats.json", &json) {
        Ok(()) => println!("wrote BENCH_cache_stats.json (per-cache serving hit rates)"),
        Err(e) => println!("(could not write BENCH_cache_stats.json: {e})"),
    }

    // ---- fault injection: clean vs disabled plan vs hard-fail ----------
    // The fault plan's contract mirrors the tracer's: armed-but-disabled
    // (rate 0, no scheduled fault) must cost exactly zero simulated
    // cycles (hard-asserted — the gate CI runs), and a hard replica
    // failure must recover bit-exact through retry/failover while
    // charging honest extra cycles for the degraded dispatch. Emitted as
    // BENCH_fault.json so CI tracks the failover cost trajectory.
    println!("===== fault injection: clean vs disabled plan vs hard-fail (4 shards, batch 16) =====");
    let fault_batch = 16usize;
    let fault_slices: Vec<&[i64]> = inputs[..fault_batch].iter().map(|t| t.data.as_slice()).collect();
    let run_mode = |plan: Option<FaultPlan>| {
        let mut cluster = Cluster::new(ClusterConfig {
            replicas: 4,
            soc: bench_soc(),
        })
        .unwrap();
        let cdep = inst
            .deploy_cluster(&mut cluster, fault_batch.div_ceil(4))
            .unwrap();
        cluster.set_fault_plan(0, plan);
        let mut sched = Scheduler::new(SchedulePolicy::LeastOutstandingCycles, 4).unwrap();
        let (outs, m) = cdep
            .run_sharded_degraded(&mut cluster, &mut sched, &fault_slices, DEFAULT_SHARD_RETRIES)
            .unwrap();
        let outs: Vec<Vec<i64>> = outs
            .into_iter()
            .enumerate()
            .map(|(i, o)| o.unwrap_or_else(|e| panic!("request {i} must be served after failover: {e}")))
            .collect();
        (outs, m, cluster.faults_injected())
    };
    let (outs_clean, m_clean, faults_clean) = run_mode(None);
    let (outs_disabled, m_disabled, faults_disabled) = run_mode(Some(FaultPlan::new(FaultConfig {
        seed: 7,
        rate: 0.0,
        ..Default::default()
    })));
    let (outs_faulted, m_faulted, faults_faulted) = run_mode(Some(FaultPlan::new(FaultConfig {
        seed: 7,
        rate: 0.0,
        hard_fail_run: Some(0),
        ..Default::default()
    })));
    // the gates: a disabled plan perturbs nothing, and a hard failure
    // recovers bit-exact at an honestly-charged cycle cost
    assert_eq!(
        m_clean.total_cycles(),
        m_disabled.total_cycles(),
        "a disabled fault plan must cost zero simulated cycles \
         (clean: {}, armed rate-0: {})",
        m_clean.total_cycles(),
        m_disabled.total_cycles()
    );
    assert_eq!(outs_clean, outs_disabled, "a disabled fault plan must not touch logits");
    assert_eq!(faults_clean, 0);
    assert_eq!(faults_disabled, 0, "a rate-0 plan never fires");
    assert_eq!(faults_faulted, 1, "the scheduled hard failure fires exactly once");
    assert_eq!(outs_faulted, outs_clean, "failover recovery must be bit-exact");
    assert!(
        m_faulted.total_cycles() > m_clean.total_cycles(),
        "a degraded dispatch charges honest extra cycles \
         (clean: {}, faulted: {})",
        m_clean.total_cycles(),
        m_faulted.total_cycles()
    );
    let mut t = Table::new(&[
        "mode",
        "cycles/req",
        "faults",
        "retries",
        "failovers",
        "quarantined",
        "vs clean",
    ]);
    let mut json_rows = Vec::new();
    for (mode, m, faults) in [
        ("clean", &m_clean, faults_clean),
        ("armed rate-0", &m_disabled, faults_disabled),
        ("hard-fail replica 0", &m_faulted, faults_faulted),
    ] {
        let per_req = m.total_cycles() as f64 / fault_batch as f64;
        let vs_clean = m.total_cycles() as f64 / m_clean.total_cycles().max(1) as f64;
        t.row(vec![
            mode.into(),
            format!("{per_req:.0}"),
            faults.to_string(),
            m.retries.to_string(),
            m.failovers.to_string(),
            m.quarantined.to_string(),
            format!("{vs_clean:.2}x"),
        ]);
        json_rows.push(format!(
            "    {{\"mode\": \"{mode}\", \"shards\": 4, \"batch\": {fault_batch}, \
             \"cycles_per_req\": {per_req:.1}, \"faults_injected\": {faults}, \
             \"retries\": {}, \"failovers\": {}, \"quarantined\": {}, \
             \"cycles_vs_clean\": {vs_clean:.4}, \"extra_cycles_disabled\": 0}}",
            m.retries, m.failovers, m.quarantined
        ));
    }
    println!("{}", t.to_ascii());
    println!("gates: disabled plan costs 0 extra cycles; failover recovery bit-exact — OK");
    let json = format!(
        "{{\n  \"bench\": \"fault\",\n  \"network\": \"tiny\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write("BENCH_fault.json", &json) {
        Ok(()) => println!("wrote BENCH_fault.json (clean vs disabled-plan vs hard-fail failover)"),
        Err(e) => println!("(could not write BENCH_fault.json: {e})"),
    }

    // ---- continuous vs fixed batching: latency under arrival load ------
    // The same real cluster driven through the simulated-time load
    // generator (`coordinator::loadgen`): open-loop Poisson arrivals at
    // fractions of the cluster's measured capacity, plus a closed-loop
    // saturation row. Continuous batching dispatches the moment the
    // worker frees; fixed holds each window for its max-wait. The gates
    // CI runs: continuous never reports a worse p99 than fixed at the
    // same arrival rate, and closed-loop saturation throughput does not
    // regress. Emitted as BENCH_slo.json so CI tracks the latency-SLO
    // trajectory.
    println!("===== continuous vs fixed batching: arrival-rate sweep (simulated µs, 4 shards, batch 16) =====");
    let slo_shards = 4usize;
    let slo_cap = 16usize;
    let clock = 200.0f64;
    let e = probe_us_per_req(&inst, slo_shards, slo_cap, clock).unwrap();
    // full waves serve `shards` requests every `e` simulated µs
    let capacity_rps = slo_shards as f64 * 1e6 / e as f64;
    println!(
        "measured cost: {e} us/request warm ({capacity_rps:.0} req/s capacity at {slo_shards} shards)"
    );
    let lg = |arrivals: Arrivals, mode: BatchMode| {
        run_loadgen(
            &inst,
            &LoadGenConfig {
                arrivals,
                mode,
                requests: 128,
                max_batch: slo_cap,
                shards: slo_shards,
                clock_mhz: clock,
                slo_p99_us: None,
                seed: 42_000,
                warmup: true,
            },
        )
        .unwrap()
    };
    let mut t = Table::new(&[
        "arrivals",
        "mode",
        "p50 (us)",
        "p95 (us)",
        "p99 (us)",
        "req/s",
        "mean batch",
        "shed",
    ]);
    let mut json_rows = Vec::new();
    let mut push = |arrivals: &str, rate_rps: f64, mode: &str, r: &LoadGenReport| {
        t.row(vec![
            arrivals.into(),
            mode.into(),
            r.p50_us.to_string(),
            r.p95_us.to_string(),
            r.p99_us.to_string(),
            format!("{:.0}", r.throughput_rps),
            format!("{:.1}", r.mean_batch),
            r.shed.to_string(),
        ]);
        json_rows.push(format!(
            "    {{\"arrivals\": \"{arrivals}\", \"mode\": \"{mode}\", \
             \"rate_rps\": {rate_rps:.0}, \"served\": {}, \"shed\": {}, \
             \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}, \
             \"throughput_rps\": {:.1}, \"mean_batch\": {:.2}}}",
            r.served, r.shed, r.p50_us, r.p95_us, r.p99_us, r.throughput_rps, r.mean_batch
        ));
    };
    for frac in [0.2f64, 0.5, 0.8] {
        let rate = capacity_rps * frac;
        let arrivals = Arrivals::Poisson {
            rate_rps: rate,
            seed: 11,
        };
        let fixed = lg(arrivals, BatchMode::Fixed { max_wait_us: 2 * e });
        let cont = lg(arrivals, BatchMode::Continuous);
        assert_eq!(fixed.mismatches + cont.mismatches, 0, "responses must be bit-exact");
        // the hard gate: continuous never loses on p99 at equal load
        // (tolerance: 2% or 1µs for rounding on the simulated clock)
        assert!(
            cont.p99_us <= fixed.p99_us + (fixed.p99_us / 50).max(1),
            "continuous p99 {}us worse than fixed {}us at {rate:.0} rps",
            cont.p99_us,
            fixed.p99_us
        );
        let label = format!("poisson {frac:.1}x cap");
        push(&label, rate, "fixed", &fixed);
        push(&label, rate, "continuous", &cont);
    }
    let closed = Arrivals::Closed {
        concurrency: 32,
        think_us: 0,
    };
    let fixed = lg(closed, BatchMode::Fixed { max_wait_us: 2 * e });
    let cont = lg(closed, BatchMode::Continuous);
    assert!(
        cont.throughput_rps >= fixed.throughput_rps * 0.98,
        "closed-loop saturation throughput regressed: continuous {:.0} vs fixed {:.0} rps",
        cont.throughput_rps,
        fixed.throughput_rps
    );
    push("closed 32", capacity_rps, "fixed", &fixed);
    push("closed 32", capacity_rps, "continuous", &cont);
    drop(push);
    println!("{}", t.to_ascii());
    println!("gates: continuous p99 <= fixed p99 at every rate; saturation throughput kept — OK");
    let json = format!(
        "{{\n  \"bench\": \"slo\",\n  \"network\": \"tiny\",\n  \"shards\": {slo_shards}, \
         \"max_batch\": {slo_cap}, \"us_per_req\": {e}, \"capacity_rps\": {capacity_rps:.0},\n  \"rows\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write("BENCH_slo.json", &json) {
        Ok(()) => println!("wrote BENCH_slo.json (continuous vs fixed latency under load)"),
        Err(e) => println!("(could not write BENCH_slo.json: {e})"),
    }

    // XLA-artifact execution path (the L1/L2 kernels through PJRT)
    match ArtifactStore::open(Path::new("artifacts")) {
        Ok(store) => match Runtime::cpu() {
            Ok(rt) => {
                let module = rt.load_hlo_text(&store.path("tiny_cnn")).unwrap();
                let args = golden::tiny_args(&inst, &inputs[0]).unwrap();
                // time 32 executions
                let t0 = Instant::now();
                let iters = 32;
                for _ in 0..iters {
                    std::hint::black_box(module.run_i32(&args).unwrap());
                }
                let per = t0.elapsed() / iters;
                println!("XLA tiny_cnn execution: {per:?} per inference ({:.0} inf/s)", 1.0 / per.as_secs_f64());
            }
            Err(e) => println!("(XLA path unavailable: {e})"),
        },
        Err(e) => println!("({e})"),
    }
    println!("e2e_serving bench complete");
}
