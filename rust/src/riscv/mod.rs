//! RV32I-subset control processor — §III's "RISC V processor [that] can
//! configure the connection between systolic cells to realize various
//! modules for CNN".
//!
//! * [`isa`] — instruction decoding (RV32I base integer subset),
//! * [`cpu`] — the instruction-set simulator with a pluggable [`cpu::Bus`]
//!   (the SoC maps the systolic engine's control registers into the
//!   address space — see `crate::accel::soc`),
//! * [`asm`] — a programmatic assembler with labels, used to author the
//!   control programs stored in instruction memory.

pub mod asm;
pub mod cpu;
pub mod isa;

pub use asm::Assembler;
pub use cpu::{Bus, Cpu, StopReason};
pub use isa::Instr;
