//! Multiplier generators — the paper's §IV.
//!
//! Every generator emits a [`crate::netlist::Netlist`] with input buses
//! `a` and `b` (`width` bits each) and output bus `p` (`2·width` bits):
//!
//! * [`karatsuba`] — the paper's contribution: recursive Karatsuba-Ofman
//!   divide-and-conquer (3 half-width products per level), with the
//!   "pipelined high speed" variant produced by levelized pipelining;
//! * [`baugh_wooley`] — signed two's-complement array multiplier baseline;
//! * [`dadda`] — Dadda column-reduction tree baseline (ripple final adder,
//!   reproducing the paper's Table 5 ordering — see DESIGN.md §9);
//! * [`wallace`] — Wallace tree with Kogge-Stone final adder (extension);
//! * [`schoolbook`] — plain shift-and-add array multiplier (extension);
//! * [`booth`] — radix-4 Booth recoding, signed (extension).

pub mod baugh_wooley;
pub mod booth;
pub mod column;
pub mod dadda;
pub mod karatsuba;
pub mod schoolbook;
pub mod wallace;

use crate::error::{Error, Result};
use crate::netlist::{pipeline_stages, Netlist};

/// Which multiplier architecture to generate.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum MultKind {
    /// Karatsuba-Ofman divide and conquer (unsigned).
    KaratsubaOfman,
    /// Baugh-Wooley two's-complement array (signed).
    BaughWooley,
    /// Dadda column-reduction tree (unsigned).
    Dadda,
    /// Wallace tree (unsigned).
    Wallace,
    /// Schoolbook array (unsigned).
    Array,
    /// Radix-4 Booth (signed).
    Booth,
}

impl MultKind {
    /// All kinds, in the paper's comparison order.
    pub const ALL: [MultKind; 6] = [
        MultKind::KaratsubaOfman,
        MultKind::BaughWooley,
        MultKind::Dadda,
        MultKind::Wallace,
        MultKind::Array,
        MultKind::Booth,
    ];

    /// Whether the architecture multiplies two's-complement operands.
    pub fn is_signed(&self) -> bool {
        matches!(self, MultKind::BaughWooley | MultKind::Booth)
    }

    /// Short CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            MultKind::KaratsubaOfman => "kom",
            MultKind::BaughWooley => "baugh-wooley",
            MultKind::Dadda => "dadda",
            MultKind::Wallace => "wallace",
            MultKind::Array => "array",
            MultKind::Booth => "booth",
        }
    }

    /// Parse a CLI name (e.g. `kom`, `dadda`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "kom" | "karatsuba" | "karatsuba-ofman" => MultKind::KaratsubaOfman,
            "bw" | "baugh-wooley" | "baughwooley" => MultKind::BaughWooley,
            "dadda" => MultKind::Dadda,
            "wallace" => MultKind::Wallace,
            "array" | "schoolbook" => MultKind::Array,
            "booth" => MultKind::Booth,
            other => return Err(Error::Usage(format!("unknown multiplier '{other}'"))),
        })
    }
}

/// Full generator specification.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MultiplierSpec {
    /// Architecture.
    pub kind: MultKind,
    /// Operand width in bits.
    pub width: u32,
    /// `Some(n)` pipelines the multiplier into `n` stages (paper's
    /// "pipelined high speed" KOM variants). `None` = combinational.
    pub stages: Option<u32>,
    /// Wrap with input/output registers (classic timing-sign-off style;
    /// used for the paper's registered Baugh-Wooley configuration).
    pub io_regs: bool,
}

impl MultiplierSpec {
    /// Combinational multiplier of `kind` × `width`.
    pub fn comb(kind: MultKind, width: u32) -> Self {
        MultiplierSpec { kind, width, stages: None, io_regs: false }
    }

    /// Pipelined multiplier.
    pub fn pipelined(kind: MultKind, width: u32, stages: u32) -> Self {
        MultiplierSpec { kind, width, stages: Some(stages), io_regs: false }
    }

    /// Combinational core with registered I/O.
    pub fn comb_regio(kind: MultKind, width: u32) -> Self {
        MultiplierSpec { kind, width, stages: None, io_regs: true }
    }

    /// The paper's Table 1–5 configurations: pipelined 16/32-bit KOM,
    /// registered-I/O 32-bit Baugh-Wooley, combinational 32-bit Dadda.
    pub fn paper_set() -> Vec<(String, MultiplierSpec)> {
        vec![
            ("16-bit KOM".into(), MultiplierSpec::pipelined(MultKind::KaratsubaOfman, 16, 3)),
            ("32-bit KOM".into(), MultiplierSpec::pipelined(MultKind::KaratsubaOfman, 32, 4)),
            ("32-bit Baugh-Wooley".into(), MultiplierSpec::comb_regio(MultKind::BaughWooley, 32)),
            ("32-bit Dadda".into(), MultiplierSpec::comb(MultKind::Dadda, 32)),
        ]
    }
}

/// A generated multiplier: the netlist plus interface metadata.
pub struct GeneratedMult {
    /// The generated netlist (inputs `a`,`b`; output `p`).
    pub netlist: Netlist,
    /// Pipeline latency in cycles (0 for combinational).
    pub latency: u32,
    /// Operand width.
    pub width: u32,
    /// Signed (two's complement) semantics.
    pub signed: bool,
    /// Spec this was generated from.
    pub spec: MultiplierSpec,
}

impl GeneratedMult {
    /// Reference product for operands `x`,`y` under this multiplier's
    /// signedness, truncated to `2*width` bits.
    pub fn reference(&self, x: u128, y: u128) -> u128 {
        let w = self.width;
        if self.signed {
            let sx = crate::bits::sign_extend(x, w);
            let sy = crate::bits::sign_extend(y, w);
            crate::bits::truncate((sx.wrapping_mul(sy)) as u128, 2 * w)
        } else {
            let mx = crate::bits::truncate(x, w);
            let my = crate::bits::truncate(y, w);
            crate::bits::truncate(mx.wrapping_mul(my), 2 * w)
        }
    }
}

/// Generate a multiplier netlist from a spec.
pub fn generate(spec: MultiplierSpec) -> Result<GeneratedMult> {
    if spec.width < 2 || spec.width > 64 {
        return Err(Error::Unsupported(format!(
            "multiplier width {} out of range [2,64]",
            spec.width
        )));
    }
    let comb = match spec.kind {
        MultKind::KaratsubaOfman => karatsuba::build(spec.width)?,
        MultKind::BaughWooley => baugh_wooley::build(spec.width)?,
        MultKind::Dadda => dadda::build(spec.width)?,
        MultKind::Wallace => wallace::build(spec.width)?,
        MultKind::Array => schoolbook::build_array(spec.width)?,
        MultKind::Booth => booth::build(spec.width)?,
    };
    let (netlist, latency) = match spec.stages {
        Some(s) if s > 1 => {
            let p = pipeline_stages(&comb, s);
            (p.netlist, p.latency)
        }
        _ if spec.io_regs => {
            let p = crate::netlist::pipeline::register_io(&comb);
            (p.netlist, p.latency)
        }
        _ => (comb, 0),
    };
    Ok(GeneratedMult {
        netlist,
        latency,
        width: spec.width,
        signed: spec.kind.is_signed(),
        spec,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run_comb, run_pipelined};

    /// Exhaustive check of every architecture at small widths.
    #[test]
    fn all_kinds_exhaustive_small() {
        for kind in MultKind::ALL {
            for width in [2u32, 3, 4] {
                if kind == MultKind::Booth && (width % 2 != 0 || width < 4) {
                    continue; // radix-4 booth needs even width >= 4
                }
                let m = generate(MultiplierSpec::comb(kind, width)).unwrap();
                for x in 0..(1u128 << width) {
                    for y in 0..(1u128 << width) {
                        let got = run_comb(&m.netlist, &[("a", x), ("b", y)], "p").unwrap();
                        let want = m.reference(x, y);
                        assert_eq!(got, want, "{kind:?} w={width} {x}*{y}");
                    }
                }
            }
        }
    }

    /// Randomised check at the paper's widths (16/32).
    #[test]
    fn all_kinds_random_paper_widths() {
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut rnd = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for kind in MultKind::ALL {
            for width in [16u32, 32] {
                let m = generate(MultiplierSpec::comb(kind, width)).unwrap();
                for _ in 0..25 {
                    let x = crate::bits::truncate(rnd() as u128, width);
                    let y = crate::bits::truncate(rnd() as u128, width);
                    let got = run_comb(&m.netlist, &[("a", x), ("b", y)], "p").unwrap();
                    assert_eq!(got, m.reference(x, y), "{kind:?} w={width} {x}*{y}");
                }
            }
        }
    }

    /// The paper's pipelined KOM variants stream correctly.
    #[test]
    fn pipelined_kom_streams() {
        for (width, stages) in [(16u32, 4u32), (32, 6)] {
            let m = generate(MultiplierSpec::pipelined(MultKind::KaratsubaOfman, width, stages)).unwrap();
            assert!(m.latency >= 1);
            let mut state = 0xdeadbeefcafef00du64;
            let mut rnd = move || {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                state
            };
            let pairs: Vec<(u128, u128)> = (0..12)
                .map(|_| {
                    (
                        crate::bits::truncate(rnd() as u128, width),
                        crate::bits::truncate(rnd() as u128, width),
                    )
                })
                .collect();
            let stream: Vec<Vec<(&str, u128)>> =
                pairs.iter().map(|&(x, y)| vec![("a", x), ("b", y)]).collect();
            let outs = run_pipelined(&m.netlist, &stream, "p", m.latency).unwrap();
            for (i, &(x, y)) in pairs.iter().enumerate() {
                assert_eq!(outs[i], m.reference(x, y), "lane {i}: {x}*{y}");
            }
        }
    }

    #[test]
    fn spec_parse_roundtrip() {
        for kind in MultKind::ALL {
            assert_eq!(MultKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(MultKind::parse("bogus").is_err());
    }
}
