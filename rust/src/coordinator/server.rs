//! The coordinator: front door, batch formation, worker pool.
//!
//! ```text
//!   submit() ──tx──► batcher thread ──work queue──► worker 0 (SoC #0)
//!                                              ├──► worker 1 (SoC #1)
//!                                              └──► …
//! ```
//!
//! Batch formation comes in two modes. The default **fixed** batcher
//! (diagrammed above) fills batches to `max_batch` or a timeout on a
//! dedicated thread. **Continuous** batching
//! (`CoordinatorConfig::continuous`) removes the thread entirely: a free
//! worker pulls whatever is queued the moment it goes idle, and the
//! dispatch size comes from the scheduler's measured cycles/request EMA
//! against the `slo_p99_us` target (see
//! [`super::batcher::SloPolicy`]) — no request ever waits for company,
//! and the front door sheds when the EMA says the SLO is unattainable.
//!
//! Each worker owns a **private accelerator** (its own `accel::Driver`
//! with the network deployed at batch capacity), mirroring a multi-card
//! serving node. Workers pull whole batches from a shared queue (work
//! stealing ≈ least-loaded routing), pack every request's input into one
//! contiguous DRAM region, execute **one** batched descriptor-table run —
//! so the accelerator sees the batch as a unit and the weight-stationary
//! engine amortises tap loads and reconfiguration across it — then fan
//! the per-request outputs back out. Malformed requests are rejected with
//! an explicit error response before the batch forms. Replica SoCs run
//! with the pipelined execution model on by default
//! (`CoordinatorConfig::pipeline`): layer DMA overlaps engine compute
//! through double-buffered scratchpad staging, and the hidden cycles are
//! reported via `StatsCollector::overlapped_cycles`. Scratchpad-resident
//! layer fusion is on by default too (`CoordinatorConfig::fuse`): chained
//! layers whose intermediates fit on-chip skip the DRAM round trip, with
//! the eliminated cycles reported via
//! `StatsCollector::fused_saved_cycles`.
//!
//! The hot path is **compile-once / execute-many**: each worker's
//! deployment compiles its descriptor tables into
//! [`crate::accel::CompiledPlan`]s at worker start, per-batch runs execute
//! cached plans (`StatsCollector::plan_cache_hit_rate`), and the engine
//! configuration-context cache (`CoordinatorConfig::config_cache`, on by
//! default) makes warm runs skip every per-layer reconfiguration
//! (`StatsCollector::reconfigs_skipped`). In front of all of that sits
//! the front-door activation cache (`CoordinatorConfig::dedup`, on by
//! default): an exact repeat of an already-served input is answered from
//! a bounded LRU result cache without forming an accelerator batch at
//! all (`StatsCollector::dedup_hits`).

use super::batcher::{BatchPolicy, Batcher, ContinuousBatcher, SloPolicy};
use super::dedup::DedupCache;
use super::request::{InferenceRequest, InferenceResponse, RequestId};
use super::stats::StatsCollector;
use crate::accel::{FaultConfig, FaultPlan, ShardedMetrics, SocConfig, DEFAULT_RING_CAPACITY};
use crate::cluster::{Cluster, ClusterConfig, SchedulePolicy, Scheduler};
use crate::cnn::networks::{ClusterDeployment, NetworkInstance, DEFAULT_SHARD_RETRIES};
use crate::cnn::tensor::Tensor;
use crate::error::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the guard if a previous holder panicked: the
/// protected state (counters, caches, the batch queue) stays internally
/// consistent across a panic, so serving must continue rather than
/// cascade the poison into every worker thread.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Coordinator sizing/policy.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker (accelerator cluster) count.
    pub workers: usize,
    /// Replicated SoCs per worker: each worker's batch is sharded
    /// data-parallel across this many accelerators and dispatched
    /// concurrently (1 = the single-SoC path).
    pub shards: usize,
    /// Shard placement policy within each worker's cluster.
    pub sched: SchedulePolicy,
    /// Overlap layer DMA with engine compute on every replica (the SoC
    /// `PIPELINE` register — double-buffered scratchpad staging). On by
    /// default: the serving hot path should not pay memory traffic it can
    /// hide. Disable to reproduce the serial cycle model.
    pub pipeline: bool,
    /// Run every replica's descriptor tables through the layer-fusion
    /// planner: chained layers whose intermediates fit the scratchpad
    /// skip the DRAM store + reload entirely. On by default — the serving
    /// hot path should not pay memory traffic it can eliminate; composes
    /// with `pipeline` (fusion removes traffic, overlap hides the rest)
    /// and with `shards`. Disable to reproduce the unfused model.
    pub fuse: bool,
    /// Enable the engine configuration-context cache on every replica:
    /// warm runs of an unchanged descriptor table skip every per-layer
    /// engine reconfiguration (0 cycles, counted in
    /// `StatsCollector::reconfigs_skipped`). On by default — the serving
    /// hot path runs the same compiled plan over and over, so after the
    /// first batch of each shape the per-run reconfiguration term is
    /// gone. Disable to reproduce the cold reconfiguration model.
    pub config_cache: bool,
    /// Exact-input request dedup at the front door: a request whose
    /// quantized input tensor is byte-identical to an already-served one
    /// is answered from a bounded LRU result cache without forming an
    /// accelerator batch (hits counted in `StatsCollector::dedup_hits`).
    /// On by default; disable with `--no-dedup` / `dedup: false` for
    /// strictly-isolated request accounting.
    pub dedup: bool,
    /// Word budget of the front-door activation cache: the sum of resident
    /// `shape + input + logits` words across cached results never exceeds
    /// this. Bounding by words (not entries) keeps host memory fixed no
    /// matter the network's input size; an input whose entry alone exceeds
    /// the budget is never cached. The default holds exactly 1024
    /// Tiny-sized entries, matching the old 1024-entry bound on Tiny
    /// traffic. Set with `serve --dedup-budget`.
    pub dedup_budget_words: usize,
    /// Arm the execution tracer on every replica: each batch's stitched
    /// per-layer cycle attribution folds into
    /// `StatsCollector::per_layer` (the hotspots table and the
    /// `kom_layer_cycles_total` metrics rows). Off by default — tracing
    /// never perturbs simulated cycles, but the ring buffer and
    /// per-batch stitching are real host work the hot path should not
    /// pay unless asked.
    pub trace: bool,
    /// Batching policy.
    pub batch: BatchPolicy,
    /// Continuous batching: instead of the fixed fill-to-`max_batch`/
    /// timeout batcher thread, a free worker admits whatever is queued
    /// *right now* — no batch ever waits for company — and sizes the
    /// dispatch dynamically from the scheduler's measured cycles/request
    /// EMA against `slo_p99_us` (see [`SloPolicy`]). Off by default; set
    /// with `serve --continuous`.
    pub continuous: bool,
    /// p99 latency target in **simulated** microseconds for continuous
    /// batching: dispatches shrink so predicted queue-wait + execution
    /// stays under it, and the front door sheds (via the `overloaded`
    /// path) when the learned EMA says even a lone request cannot meet
    /// it. `None` = no target: continuous mode takes everything queued up
    /// to `max_batch`. Set with `serve --slo-p99-us`.
    pub slo_p99_us: Option<u64>,
    /// Per-replica SoC configuration.
    pub soc: SocConfig,
    /// Simulated accelerator clock (MHz) used to convert cycles into
    /// simulated service time for reporting.
    pub clock_mhz: f64,
    /// Bound on requests admitted into the serving pipeline and not yet
    /// picked up by a worker (`0` = unbounded, the legacy behavior). A
    /// submission over the bound is **shed** at the front door: it gets
    /// an immediate, explicit `overloaded` failure response — never a
    /// dropped channel — and occupies no batcher slot. Set with `serve
    /// --queue-depth`.
    pub queue_depth: usize,
    /// Per-request service deadline. A request older than this when its
    /// worker forms the batch is failed explicitly *before* the
    /// accelerator run, so expired work never wastes cycles. `None` =
    /// no deadline. Set with `serve --deadline-us`.
    pub deadline: Option<Duration>,
    /// Deterministic fault-injection seed: `Some` arms a seeded
    /// [`FaultPlan`] (rate `fault_rate`) on replica 0 of every worker's
    /// cluster — the robustness drill behind `--fault-seed`. `None`
    /// (default) leaves every replica unarmed, cycle-identical to the
    /// pre-fault build.
    pub fault_seed: Option<u64>,
    /// Per-DMA-site injection probability used when `fault_seed` is
    /// armed. Set with `--fault-rate`.
    pub fault_rate: f64,
    /// Schedule a one-shot hard failure on replica 0's K-th batch run
    /// (requires `fault_seed`). Deterministic drills and tests only — no
    /// CLI flag.
    pub fault_hard_fail_run: Option<u64>,
    /// Bounded retry attempts a faulted shard gets on healthy replicas
    /// before its requests surface per-request errors (sibling requests
    /// in the batch are unaffected either way).
    pub shard_retries: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            shards: 1,
            sched: SchedulePolicy::LeastOutstandingCycles,
            pipeline: true,
            fuse: true,
            config_cache: true,
            dedup: true,
            dedup_budget_words: DedupCache::DEFAULT_BUDGET_WORDS,
            trace: false,
            batch: BatchPolicy::default(),
            continuous: false,
            slo_p99_us: None,
            soc: SocConfig::serving(),
            clock_mhz: 200.0,
            queue_depth: 0,
            deadline: None,
            fault_seed: None,
            fault_rate: 0.0,
            fault_hard_fail_run: None,
            shard_retries: DEFAULT_SHARD_RETRIES,
        }
    }
}

/// Argmax class readout for a response — one definition so the dedup-hit
/// and accelerator paths can never classify the same logits differently.
fn class_of(logits: &[i64]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by_key(|(_, &v)| v)
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Where a worker gets its next batch: the fixed batcher thread's output
/// channel, or the shared continuous batcher pulled directly. Workers
/// serialize on the inner mutex only while *forming* a batch (the recv);
/// execution happens after the guard drops, so shards still run
/// concurrently across workers.
#[derive(Clone)]
enum BatchSource {
    Fixed(Arc<Mutex<Receiver<Vec<InferenceRequest>>>>),
    Continuous(Arc<Mutex<ContinuousBatcher>>),
}

impl BatchSource {
    /// Block for the next batch; `None` on shutdown. `ema_cycles_per_req`
    /// feeds the continuous batcher's SLO sizing (ignored by the fixed
    /// path).
    fn next(&self, ema_cycles_per_req: u64) -> Option<Vec<InferenceRequest>> {
        match self {
            // a panicking sibling poisons the shared mutex; the receiver
            // itself is still coherent, so recover the guard and keep
            // serving
            BatchSource::Fixed(rx) => lock_recover(rx).recv().ok(),
            BatchSource::Continuous(b) => lock_recover(b).next_batch(ema_cycles_per_req),
        }
    }
}

struct Worker {
    cluster: Cluster,
    cdep: ClusterDeployment,
    sched: Scheduler,
    /// Total batch capacity across the worker's shards.
    capacity: usize,
    /// Expected per-request input shape, for upfront validation.
    input_dims: Vec<usize>,
    /// Bounded retry attempts per faulted shard.
    shard_retries: usize,
    /// Cluster-cumulative fault count at the last stats report, so each
    /// batch records only its own delta.
    faults_seen: u64,
}

impl Worker {
    fn build(cfg: &CoordinatorConfig, inst: &NetworkInstance) -> Result<Self> {
        let max_batch = cfg.batch.max_batch.max(1);
        // a batch of max_batch splits into shards of at most ⌈max/shards⌉
        let per_shard = max_batch.div_ceil(cfg.shards);
        let mut cluster = Cluster::new(ClusterConfig {
            replicas: cfg.shards,
            soc: cfg.soc,
        })?;
        cluster.set_pipeline(cfg.pipeline)?;
        cluster.set_fusion(cfg.fuse);
        cluster.set_config_cache(cfg.config_cache);
        if cfg.trace {
            cluster.set_tracing(DEFAULT_RING_CAPACITY);
        }
        // deploy_cluster compiles every replica's full-capacity plan here,
        // at worker start — the per-batch hot loop only executes plans
        let cdep = inst.deploy_cluster(&mut cluster, per_shard)?;
        if let Some(seed) = cfg.fault_seed {
            // the drill arms exactly one replica (0) per worker: the
            // other replicas stay healthy failover targets
            cluster.set_fault_plan(
                0,
                Some(FaultPlan::new(FaultConfig {
                    seed,
                    rate: cfg.fault_rate,
                    hard_fail_run: cfg.fault_hard_fail_run,
                    ..Default::default()
                })),
            );
        }
        let sched = Scheduler::new(cfg.sched, cfg.shards)?;
        let input_dims = inst.net.input.dims();
        Ok(Worker {
            cluster,
            cdep,
            sched,
            capacity: per_shard * cfg.shards,
            input_dims,
            shard_retries: cfg.shard_retries,
            faults_seen: 0,
        })
    }

    /// Reject inputs whose shape does not match the deployed network
    /// *before* they join a batch (a wrong-sized write would otherwise
    /// silently corrupt neighbouring DRAM regions).
    fn validate(&self, input: &Tensor) -> Result<()> {
        if input.shape != self.input_dims || input.len() != self.cdep.in_len() {
            return Err(Error::Shape(format!(
                "input shape {:?} does not match network input {:?}",
                input.shape, self.input_dims
            )));
        }
        Ok(())
    }

    /// Run a whole batch sharded across the worker's cluster: split it
    /// data-parallel over the replicas, dispatch one batched
    /// descriptor-table run per shard concurrently, and reassemble the
    /// per-request logits. Per-request `Result`s: a shard that faults
    /// past its bounded retries fails only its own requests. Returns the
    /// [`ShardedMetrics`] aggregate whose total is the max over each
    /// replica's serial work (the parallel-completion model).
    fn infer_batch(
        &mut self,
        inputs: &[&Tensor],
    ) -> Result<(Vec<Result<Vec<i64>>>, ShardedMetrics)> {
        let n = inputs.len();
        if n == 0 || n > self.capacity {
            return Err(Error::Coordinator(format!(
                "batch of {n} exceeds deployed capacity {}",
                self.capacity
            )));
        }
        let slices: Vec<&[i64]> = inputs.iter().map(|t| t.data.as_slice()).collect();
        self.cdep.run_sharded_degraded(
            &mut self.cluster,
            &mut self.sched,
            &slices,
            self.shard_retries,
        )
    }
}

/// The running coordinator.
pub struct Coordinator {
    tx: Option<Sender<InferenceRequest>>,
    batcher_handle: Option<JoinHandle<()>>,
    worker_handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    /// Front-door activation cache (exact-input dedup), `None` when
    /// disabled. Consulted in [`Coordinator::submit`] — a hit answers
    /// immediately and never occupies a batcher slot; workers insert
    /// served results.
    dedup: Option<Arc<Mutex<DedupCache>>>,
    /// Requests admitted into the pipeline and not yet picked up by a
    /// worker — the quantity [`CoordinatorConfig::queue_depth`] bounds.
    queued: Arc<AtomicUsize>,
    /// The admission bound (0 = unbounded).
    queue_depth: usize,
    /// Raised by [`Coordinator::shutdown`] before the channels close:
    /// workers answer every still-queued request with an explicit
    /// "shutting down" failure instead of serving (or dropping) it.
    shutting: Arc<AtomicBool>,
    /// SLO sizing/admission policy (inert when `slo_p99_us` is `None`).
    slo: SloPolicy,
    /// Latest cycles/request EMA published by any worker's scheduler
    /// (they serve identical replicas, so last-writer-wins is exact
    /// enough). Read by [`Coordinator::submit`] for SLO admission and by
    /// the continuous batcher for dispatch sizing. Starts at the
    /// scheduler's cold estimate of 1.
    ema: Arc<AtomicU64>,
    /// Shared statistics.
    pub stats: Arc<Mutex<StatsCollector>>,
}

impl Coordinator {
    /// Start the batcher and worker pool for a network instance.
    pub fn start(cfg: CoordinatorConfig, inst: &NetworkInstance) -> Result<Self> {
        if cfg.workers == 0 {
            return Err(Error::Coordinator("need at least one worker".into()));
        }
        if cfg.shards == 0 {
            return Err(Error::Coordinator(
                "need at least one shard (SoC replica) per worker".into(),
            ));
        }
        let (tx, rx) = channel::<InferenceRequest>();
        let stats = Arc::new(Mutex::new(StatsCollector::new()));
        let queued = Arc::new(AtomicUsize::new(0));
        let shutting = Arc::new(AtomicBool::new(false));
        // the scheduler's cold cycles/request estimate, shared so the
        // continuous batcher and the front door see what workers learn
        let ema = Arc::new(AtomicU64::new(1));
        let slo = SloPolicy {
            max_batch: cfg.batch.max_batch.max(1),
            shards: cfg.shards,
            clock_mhz: cfg.clock_mhz,
            slo_p99_us: cfg.slo_p99_us,
        };
        // one activation cache behind the whole front door: a repeat can
        // hit no matter which worker served the original
        let dedup = cfg
            .dedup
            .then(|| Arc::new(Mutex::new(DedupCache::new(cfg.dedup_budget_words))));

        // batch formation: continuous mode pulls straight off the
        // submission channel (no batcher thread, nothing ever waits for
        // company); fixed mode keeps the fill-to-max/timeout thread
        let mut batcher_handle = None;
        let source = if cfg.continuous {
            BatchSource::Continuous(Arc::new(Mutex::new(ContinuousBatcher::new(rx, slo))))
        } else {
            let (batch_tx, batch_rx) = channel::<Vec<InferenceRequest>>();
            let policy = cfg.batch;
            let handle = std::thread::Builder::new()
                .name("kom-batcher".into())
                .spawn(move || {
                    let b = Batcher::new(rx, policy);
                    while let Some(batch) = b.next_batch() {
                        if batch_tx.send(batch).is_err() {
                            break; // workers gone
                        }
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn batcher: {e}")))?;
            batcher_handle = Some(handle);
            BatchSource::Fixed(Arc::new(Mutex::new(batch_rx)))
        };

        // worker pool
        let mut worker_handles = Vec::new();
        for wid in 0..cfg.workers {
            let mut worker = Worker::build(&cfg, inst)?;
            let source = source.clone();
            let stats = Arc::clone(&stats);
            let dedup = dedup.clone();
            let queued = Arc::clone(&queued);
            let shutting = Arc::clone(&shutting);
            let ema = Arc::clone(&ema);
            let deadline = cfg.deadline;
            let handle = std::thread::Builder::new()
                .name(format!("kom-worker-{wid}"))
                .spawn(move || loop {
                    let batch = source.next(ema.load(Ordering::Acquire));
                    let Some(batch) = batch else { break };
                    let picked = Instant::now();
                    // these requests have left the admission queue
                    queued.fetch_sub(batch.len(), Ordering::AcqRel);
                    if shutting.load(Ordering::Acquire) {
                        // drain, don't serve: every queued request gets an
                        // explicit shutdown failure, never a dropped
                        // channel
                        for req in batch {
                            let latency_us = req.submitted.elapsed().as_micros() as u64;
                            let _ = req.reply.send(InferenceResponse::failure(
                                req.id,
                                wid,
                                latency_us,
                                "coordinator shutting down".into(),
                            ));
                        }
                        continue;
                    }
                    // reject expired and malformed requests with explicit
                    // error responses before the accelerator batch forms —
                    // neither may cost accelerator cycles
                    let mut valid = Vec::with_capacity(batch.len());
                    for req in batch {
                        if let Some(dl) = deadline {
                            let age = req.submitted.elapsed();
                            if age > dl {
                                let mut s = lock_recover(&stats);
                                s.record_deadline_expired();
                                s.record_error();
                                drop(s);
                                let _ = req.reply.send(InferenceResponse::failure(
                                    req.id,
                                    wid,
                                    age.as_micros() as u64,
                                    format!(
                                        "deadline exceeded: waited {}us of {}us",
                                        age.as_micros(),
                                        dl.as_micros()
                                    ),
                                ));
                                continue;
                            }
                        }
                        match worker.validate(&req.input) {
                            Ok(()) => {
                                let wait_us =
                                    picked.saturating_duration_since(req.submitted).as_micros()
                                        as u64;
                                valid.push((req, wait_us));
                            }
                            Err(e) => {
                                lock_recover(&stats).record_error();
                                let latency_us = req.submitted.elapsed().as_micros() as u64;
                                let _ = req.reply.send(InferenceResponse::failure(
                                    req.id,
                                    wid,
                                    latency_us,
                                    e.to_string(),
                                ));
                            }
                        }
                    }
                    if valid.is_empty() {
                        continue;
                    }
                    {
                        // the dispatch is now shaped: log the size the
                        // batcher chose and how long each rider queued
                        let mut s = lock_recover(&stats);
                        s.record_batch_size(valid.len());
                        for &(_, wait_us) in &valid {
                            s.record_queue_wait(wait_us);
                        }
                    }
                    let result = {
                        let inputs: Vec<&Tensor> = valid.iter().map(|(r, _)| &r.input).collect();
                        worker.infer_batch(&inputs)
                    };
                    // publish the scheduler's learned cycles/request
                    // *before* any response goes out: a client that has
                    // received an answer may immediately probe SLO
                    // admission, which must see at least this batch's EMA
                    ema.store(worker.sched.cycles_per_req_ema(), Ordering::Release);
                    match result {
                        Ok((outs, m)) => {
                            let n = valid.len();
                            let cycles = m.total_cycles();
                            let per_shard: Vec<(usize, u64)> = m
                                .shards
                                .iter()
                                .map(|s| (s.replica, s.metrics.total_cycles()))
                                .collect();
                            let latencies: Vec<u64> = valid
                                .iter()
                                .map(|(r, _)| r.submitted.elapsed().as_micros() as u64)
                                .collect();
                            // drain the batch's stitched trace (if armed)
                            // before the lock: stitching walks the rings,
                            // folding it is one cheap merge under the lock
                            let trace = worker
                                .cluster
                                .tracing_enabled()
                                .then(|| worker.cluster.take_stitched_trace(&m));
                            // fault/recovery telemetry: the injected count
                            // is cluster-cumulative, so report the delta
                            let injected = worker.cluster.faults_injected();
                            let fault_delta = injected - worker.faults_seen;
                            worker.faults_seen = injected;
                            let quarantine: Vec<bool> = (0..worker.cluster.len())
                                .map(|r| worker.sched.is_quarantined(r))
                                .collect();
                            {
                                // one lock for the whole batch: the batch
                                // is charged its critical-path (max over
                                // shards) cycles once, each shard logs its
                                // own busy time, requests carry latency
                                let mut s = lock_recover(&stats);
                                s.record_sharded_batch(&per_shard);
                                s.record_overlapped(m.overlapped_cycles());
                                s.record_fused_saved(m.fused_saved_cycles());
                                s.record_plan_telemetry(
                                    m.reconfigs(),
                                    m.reconfigs_skipped(),
                                    m.ctx_evictions(),
                                    m.plan_hits(),
                                    m.shards.len() as u64,
                                );
                                s.record_cache_stats(wid, &worker.cluster.cache_stats());
                                s.record_fault_telemetry(fault_delta, m.retries, m.failovers);
                                s.record_quarantine(wid, &quarantine);
                                if let Some(t) = &trace {
                                    s.record_trace(t);
                                }
                                for (&latency_us, out) in latencies.iter().zip(&outs) {
                                    match out {
                                        Ok(_) => s.record(latency_us, n, 0),
                                        Err(_) => s.record_error(),
                                    }
                                }
                            }
                            for (((req, queue_wait_us), out), latency_us) in
                                valid.into_iter().zip(outs).zip(latencies)
                            {
                                match out {
                                    Ok(logits) => {
                                        if let Some(d) = dedup.as_ref() {
                                            lock_recover(d).insert(&req.input, logits.clone());
                                        }
                                        let class = class_of(&logits);
                                        let _ = req.reply.send(InferenceResponse {
                                            id: req.id,
                                            logits,
                                            class,
                                            latency_us,
                                            queue_wait_us,
                                            batch_size: n,
                                            worker: wid,
                                            accel_cycles: cycles,
                                            error: None,
                                        });
                                    }
                                    // a shard that exhausted its retries
                                    // fails its own requests; the rest of
                                    // the batch was answered normally
                                    Err(e) => {
                                        let _ = req.reply.send(InferenceResponse::failure(
                                            req.id,
                                            wid,
                                            latency_us,
                                            e.to_string(),
                                        ));
                                    }
                                }
                            }
                        }
                        Err(e) => {
                            // batch-level failure: every rider gets an
                            // explicit error, never a dropped channel
                            let msg = e.to_string();
                            {
                                let mut s = lock_recover(&stats);
                                for _ in 0..valid.len() {
                                    s.record_error();
                                }
                            }
                            for (req, _) in valid {
                                let latency_us = req.submitted.elapsed().as_micros() as u64;
                                let _ = req.reply.send(InferenceResponse::failure(
                                    req.id,
                                    wid,
                                    latency_us,
                                    msg.clone(),
                                ));
                            }
                        }
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn worker: {e}")))?;
            worker_handles.push(handle);
        }

        Ok(Coordinator {
            tx: Some(tx),
            batcher_handle,
            worker_handles,
            next_id: AtomicU64::new(0),
            dedup,
            queued,
            queue_depth: cfg.queue_depth,
            shutting,
            slo,
            ema,
            stats,
        })
    }

    /// Submit an inference; returns the response channel and the id.
    ///
    /// This is the dedup front door: an exact repeat of an already-served
    /// input is answered right here from the activation cache — real
    /// logits, zero accelerator cycles, no batcher slot, no batching
    /// wait — before anything is enqueued.
    ///
    /// Behind the cache sits admission control: with a
    /// [`CoordinatorConfig::queue_depth`] bound, a submission that finds
    /// the queue full is **shed** — answered immediately with an explicit
    /// `overloaded` failure response (the call still returns `Ok`; the
    /// refusal arrives on the reply channel like any other outcome, never
    /// as a dropped channel).
    pub fn submit(&self, input: Tensor) -> Result<(RequestId, Receiver<InferenceResponse>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        let submitted = Instant::now();
        if let Some(d) = self.dedup.as_ref() {
            // hash outside the lock: concurrent submitters only serialize
            // on the map probe + byte-verify, not on O(input) hashing
            let fp = super::dedup::fingerprint(&input);
            let cached = lock_recover(d).get_keyed(fp, &input);
            if let Some(logits) = cached {
                let latency_us = submitted.elapsed().as_micros() as u64;
                lock_recover(&self.stats).record_dedup_hit(latency_us);
                let class = class_of(&logits);
                let _ = reply.send(InferenceResponse {
                    id,
                    logits,
                    class,
                    latency_us,
                    // a hit never queues
                    queue_wait_us: 0,
                    // 0 = never reached an accelerator
                    batch_size: 0,
                    // served by the front door itself, not a worker
                    worker: 0,
                    accel_cycles: 0,
                    error: None,
                });
                return Ok((id, rx));
            }
        }
        // SLO admission: when the learned cycles/request EMA says even a
        // lone request dispatched alone cannot meet the p99 target, no
        // batch sizing can save it — queueing it would only manufacture a
        // guaranteed miss, so shed explicitly through the same
        // `overloaded` path as the depth bound. (Always attainable with
        // no SLO configured, and under the cold estimate.)
        let ema = self.ema.load(Ordering::Acquire);
        if !self.slo.attainable(ema) {
            lock_recover(&self.stats).record_shed();
            let latency_us = submitted.elapsed().as_micros() as u64;
            let _ = reply.send(InferenceResponse::failure(
                id,
                0,
                latency_us,
                Error::Overloaded(format!(
                    "p99 SLO {}us unattainable at {}us/request — request shed",
                    self.slo.slo_p99_us.unwrap_or(0),
                    self.slo.us_per_req(ema)
                ))
                .to_string(),
            ));
            return Ok((id, rx));
        }
        // bounded admission: claim a queue slot or shed. The CAS loop
        // (rather than a blind increment) means concurrent submitters can
        // never overshoot the bound.
        if self.queue_depth > 0 {
            let mut cur = self.queued.load(Ordering::Acquire);
            loop {
                if cur >= self.queue_depth {
                    lock_recover(&self.stats).record_shed();
                    let latency_us = submitted.elapsed().as_micros() as u64;
                    let _ = reply.send(InferenceResponse::failure(
                        id,
                        0,
                        latency_us,
                        Error::Overloaded(format!(
                            "submission queue at depth {} — request shed",
                            self.queue_depth
                        ))
                        .to_string(),
                    ));
                    return Ok((id, rx));
                }
                match self.queued.compare_exchange_weak(
                    cur,
                    cur + 1,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        } else {
            // unbounded: the count still tracks occupancy for the gauge
            self.queued.fetch_add(1, Ordering::AcqRel);
        }
        let send = self
            .tx
            .as_ref()
            .ok_or_else(|| Error::Coordinator("coordinator stopped".into()))
            .and_then(|tx| {
                tx.send(InferenceRequest {
                    id,
                    input,
                    submitted,
                    reply,
                })
                .map_err(|_| Error::Coordinator("submission channel closed".into()))
            });
        if let Err(e) = send {
            // the claimed slot must be released on every failure path
            self.queued.fetch_sub(1, Ordering::AcqRel);
            return Err(e);
        }
        Ok((id, rx))
    }

    /// Render the live Prometheus-style metrics page (see
    /// [`StatsCollector::metrics_text`]) — what `kom-accel serve
    /// --metrics-interval` prints while serving.
    pub fn metrics_text(&self) -> String {
        // the dedup cache is owned here, not by a worker, so its counter
        // snapshot is folded into the collector at render time
        let snap = self.dedup.as_ref().map(|d| lock_recover(d).stats());
        let mut s = lock_recover(&self.stats);
        if let Some(snap) = snap {
            s.record_dedup_cache(snap);
        }
        s.metrics_text()
    }

    /// Requests currently admitted and waiting for a worker.
    pub fn queued_len(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Drain and stop; returns the final statistics.
    ///
    /// Every request still queued when shutdown begins receives an
    /// explicit "coordinator shutting down" failure response — a waiting
    /// client's `recv()` always yields a response, never a disconnected
    /// channel.
    pub fn shutdown(mut self) -> StatsCollector {
        // raise the flag *before* closing the front door: anything the
        // batcher still flushes is answered with a shutdown failure
        self.shutting.store(true, Ordering::Release);
        drop(self.tx.take()); // closes front door; batcher drains then exits
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
        // final dedup counter snapshot, now that every insert has landed
        if let Some(d) = self.dedup.as_ref() {
            let snap = lock_recover(d).stats();
            lock_recover(&self.stats).record_dedup_cache(snap);
        }
        Arc::try_unwrap(std::mem::replace(
            &mut self.stats,
            Arc::new(Mutex::new(StatsCollector::new())),
        ))
        .map(|m| m.into_inner().unwrap_or_else(|e| e.into_inner()))
        .unwrap_or_default()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::networks::{Network, NetworkKind};
    use std::time::Duration;

    fn tiny_instance() -> NetworkInstance {
        NetworkInstance::random(Network::build(NetworkKind::Tiny), 42).unwrap()
    }

    #[test]
    fn serves_requests_correctly() {
        let inst = tiny_instance();
        let coord = Coordinator::start(CoordinatorConfig::default(), &inst).unwrap();
        let inputs: Vec<Tensor> = (0..12)
            .map(|i| Tensor::random(vec![1, 16, 16], 127, 1000 + i))
            .collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        for ((id, rx), input) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, id);
            let want = inst.forward_ref(input).unwrap();
            assert_eq!(resp.logits, want.data, "req {id}");
            assert_eq!(resp.class, want.argmax());
            assert!(resp.batch_size >= 1);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.count(), 12);
    }

    #[test]
    fn no_request_lost_under_load() {
        let inst = tiny_instance();
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 4,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let n = 64;
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                coord
                    .submit(Tensor::random(vec![1, 16, 16], 127, i as u64))
                    .unwrap()
            })
            .collect();
        let mut seen = std::collections::HashSet::new();
        for (id, rx) in rxs {
            let resp = rx.recv().expect("response");
            assert!(seen.insert(resp.id), "duplicate id {}", resp.id);
            assert_eq!(resp.id, id);
        }
        assert_eq!(seen.len(), n);
        let stats = coord.shutdown();
        assert_eq!(stats.count(), n);
    }

    #[test]
    fn malformed_shape_gets_explicit_error_response() {
        let inst = tiny_instance();
        let coord = Coordinator::start(CoordinatorConfig::default(), &inst).unwrap();
        let good_input = Tensor::random(vec![1, 16, 16], 127, 5);
        let (good_id, good_rx) = coord.submit(good_input.clone()).unwrap();
        // wrong rank *and* wrong volume
        let (bad_id, bad_rx) = coord.submit(Tensor::random(vec![5, 5], 127, 6)).unwrap();
        let bad = bad_rx
            .recv()
            .expect("failed request must get an explicit response, not a dropped channel");
        assert_eq!(bad.id, bad_id);
        assert!(!bad.is_ok());
        assert!(bad.error.as_deref().unwrap_or("").contains("shape"), "{:?}", bad.error);
        assert!(bad.logits.is_empty());
        // the malformed request must not poison the rest of its batch
        let good = good_rx.recv().expect("valid request still served");
        assert_eq!(good.id, good_id);
        assert!(good.is_ok());
        let want = inst.forward_ref(&good_input).unwrap();
        assert_eq!(good.logits, want.data);
        let stats = coord.shutdown();
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.count(), 1, "only the valid request counts as served");
    }

    #[test]
    fn batched_responses_report_amortized_stats() {
        let inst = tiny_instance();
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let rxs: Vec<_> = (0..16)
            .map(|i| coord.submit(Tensor::random(vec![1, 16, 16], 127, 300 + i)).unwrap())
            .collect();
        for (_, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok());
            assert!(resp.accel_cycles > 0, "batch cycles reported per response");
        }
        let stats = coord.shutdown();
        assert_eq!(stats.count(), 16);
        assert!(stats.batches >= 1, "at least one accelerator batch ran");
        assert!(stats.batches as usize <= 16);
        assert!(stats.mean_batch_cycles() > 0.0);
        assert!(stats.amortized_cycles_per_request() > 0.0);
        // total cycles are accounted per batch, not per request: the sum
        // over batch runs equals the collector total
        assert!(
            (stats.mean_batch_cycles() * stats.batches as f64 - stats.accel_cycles as f64).abs()
                < 1e-6
        );
    }

    #[test]
    fn sharded_worker_serves_bit_exact_and_reports_utilization() {
        let inst = tiny_instance();
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                shards: 3,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..10)
            .map(|i| Tensor::random(vec![1, 16, 16], 127, 7000 + i))
            .collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        for ((id, rx), input) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, id);
            assert!(resp.is_ok(), "{:?}", resp.error);
            let want = inst.forward_ref(input).unwrap();
            assert_eq!(resp.logits, want.data, "request {id} through 3 shards");
        }
        let stats = coord.shutdown();
        assert_eq!(stats.count(), 10);
        let busy = stats.shard_busy_cycles().to_vec();
        assert!(!busy.is_empty() && busy.iter().any(|&c| c > 0), "{busy:?}");
        assert!(busy.len() <= 3, "slots are per-cluster replicas: {busy:?}");
    }

    #[test]
    fn pipelined_serving_stays_bit_exact_and_records_overlap() {
        let inst = tiny_instance();
        // pipeline on (the default): answers must still equal forward_ref,
        // and the workers must report hidden DMA cycles
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..8)
            .map(|i| Tensor::random(vec![1, 16, 16], 127, 9000 + i))
            .collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        for ((id, rx), input) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, id);
            assert!(resp.is_ok(), "{:?}", resp.error);
            let want = inst.forward_ref(input).unwrap();
            assert_eq!(resp.logits, want.data, "request {id} under pipelining");
        }
        let stats = coord.shutdown();
        assert!(stats.overlapped_cycles > 0, "pipelining must hide DMA traffic");
        assert!(stats.overlap_fraction() > 0.0 && stats.overlap_fraction() < 1.0);

        // pipeline off: the serial model hides nothing
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                pipeline: false,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let (_, rx) = coord
            .submit(Tensor::random(vec![1, 16, 16], 127, 9100))
            .unwrap();
        assert!(rx.recv().unwrap().is_ok());
        let stats = coord.shutdown();
        assert_eq!(stats.overlapped_cycles, 0);
    }

    #[test]
    fn fused_serving_stays_bit_exact_and_records_savings() {
        let inst = tiny_instance();
        // fusion on (the default): answers must still equal forward_ref,
        // and the workers must report eliminated DMA cycles
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..8)
            .map(|i| Tensor::random(vec![1, 16, 16], 127, 9500 + i))
            .collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        for ((id, rx), input) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, id);
            assert!(resp.is_ok(), "{:?}", resp.error);
            let want = inst.forward_ref(input).unwrap();
            assert_eq!(resp.logits, want.data, "request {id} under fusion");
        }
        let stats = coord.shutdown();
        assert!(stats.fused_saved_cycles > 0, "fusion must skip DMA traffic");
        assert!(stats.fused_fraction() > 0.0 && stats.fused_fraction() < 1.0);

        // fusion off: nothing is skipped
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                fuse: false,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let (_, rx) = coord
            .submit(Tensor::random(vec![1, 16, 16], 127, 9600))
            .unwrap();
        assert!(rx.recv().unwrap().is_ok());
        let stats = coord.shutdown();
        assert_eq!(stats.fused_saved_cycles, 0);
    }

    #[test]
    fn dedup_answers_exact_repeats_from_the_front_door() {
        let inst = tiny_instance();
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let input = Tensor::random(vec![1, 16, 16], 127, 4242);
        let want = inst.forward_ref(&input).unwrap();
        // serve the original and wait for it, so the repeat is a
        // guaranteed cache hit (not a same-batch ride-along)
        let (_, rx) = coord.submit(input.clone()).unwrap();
        let first = rx.recv().unwrap();
        assert!(first.is_ok());
        assert_eq!(first.logits, want.data);
        // the exact repeat: same logits, zero accelerator cycles
        let (_, rx) = coord.submit(input.clone()).unwrap();
        let hit = rx.recv().unwrap();
        assert!(hit.is_ok());
        assert_eq!(hit.logits, want.data, "dedup hit must be bit-exact");
        assert_eq!(hit.class, want.argmax());
        assert_eq!(hit.accel_cycles, 0, "a hit never reached an accelerator");
        assert_eq!(hit.batch_size, 0);
        // a different input is not a hit
        let other = Tensor::random(vec![1, 16, 16], 127, 4243);
        let (_, rx) = coord.submit(other.clone()).unwrap();
        let miss = rx.recv().unwrap();
        assert_eq!(miss.logits, inst.forward_ref(&other).unwrap().data);
        let stats = coord.shutdown();
        assert_eq!(stats.dedup_hits, 1);
        assert_eq!(stats.count(), 3, "hits count as served requests");

        // --no-dedup: the repeat runs on the accelerator again
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                dedup: false,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        for _ in 0..2 {
            let (_, rx) = coord.submit(input.clone()).unwrap();
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok());
            assert_eq!(resp.logits, want.data);
            assert!(resp.accel_cycles > 0, "no front-door cache to hit");
        }
        let stats = coord.shutdown();
        assert_eq!(stats.dedup_hits, 0);
    }

    #[test]
    fn warm_serving_skips_reconfigurations_and_hits_the_plan_cache() {
        let inst = tiny_instance();
        // max_batch 1 makes every accelerator batch the same shape, so
        // the plan compiled at worker start serves every run — the hit
        // rate and skip counts below are deterministic, not timing-bound
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                dedup: false,
                batch: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::from_micros(100),
                },
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let n_layers = 6u64; // Tiny: conv/pool/conv/pool/fc/fc
        let distinct = 5u64; // …whose two pool layers share one configuration
        let runs = 4u64;
        for i in 0..runs {
            let (_, rx) = coord
                .submit(Tensor::random(vec![1, 16, 16], 127, 9900 + i))
                .unwrap();
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok(), "{:?}", resp.error);
        }
        let stats = coord.shutdown();
        assert_eq!(stats.plan_runs, runs);
        assert_eq!(stats.plan_hits, runs, "every run executed the deploy-time plan");
        assert!((stats.plan_cache_hit_rate() - 1.0).abs() < 1e-12);
        assert_eq!(stats.reconfigs, distinct, "only the first run configures");
        assert_eq!(
            stats.reconfigs_skipped,
            runs * n_layers - distinct,
            "warm runs skip every per-layer reconfiguration (and the cold \
             run already skips the repeated pool configuration)"
        );

        // with the context cache disabled, every run reconfigures cold
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                dedup: false,
                config_cache: false,
                batch: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::from_micros(100),
                },
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        for i in 0..2 {
            let (_, rx) = coord
                .submit(Tensor::random(vec![1, 16, 16], 127, 9950 + i))
                .unwrap();
            assert!(rx.recv().unwrap().is_ok());
        }
        let stats = coord.shutdown();
        assert_eq!(stats.reconfigs, 2 * n_layers);
        assert_eq!(stats.reconfigs_skipped, 0);
    }

    #[test]
    fn traced_serving_aggregates_per_layer_cycles() {
        let inst = tiny_instance();
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                shards: 2,
                trace: true,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                coord
                    .submit(Tensor::random(vec![1, 16, 16], 127, 8800 + i))
                    .unwrap()
            })
            .collect();
        for (_, rx) in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let metrics = coord.metrics_text();
        assert!(metrics.contains("kom_layer_cycles_total{layer=\"0\",kind=\"compute\"}"));
        // every cache instance is scraped per replica, plus the shared
        // front-door dedup cache
        for cache in ["weight", "context", "plan"] {
            for replica in 0..2 {
                assert!(
                    metrics.contains(&format!(
                        "kom_cache_hits_total{{cache=\"{cache}\",worker=\"0\",replica=\"{replica}\"}}"
                    )),
                    "missing {cache} rows for replica {replica}:\n{metrics}"
                );
            }
        }
        assert!(metrics.contains("kom_cache_hits_total{cache=\"dedup\"}"));
        let stats = coord.shutdown();
        // Tiny is 6 layers deep; every one must have attributed cycles
        assert_eq!(stats.per_layer().len(), 6);
        assert!(stats.per_layer().iter().all(|r| r.busy() > 0));
        assert!(!stats.hotspots(3).is_empty());
        // the trace is the ledger: traced compute+reconfig can never
        // undercount the charged accelerator cycles (sums over shards,
        // while the batch charge is the max)
        let traced: u64 = stats.per_layer().iter().map(|r| r.busy()).sum();
        assert!(traced >= stats.accel_cycles, "{traced} < {}", stats.accel_cycles);

        // tracing off (the default): no per-layer rows exist
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let (_, rx) = coord
            .submit(Tensor::random(vec![1, 16, 16], 127, 8900))
            .unwrap();
        assert!(rx.recv().unwrap().is_ok());
        let stats = coord.shutdown();
        assert!(stats.per_layer().is_empty());
    }

    #[test]
    fn faulted_shard_fails_only_its_own_requests() {
        let inst = tiny_instance();
        // deterministic drill: replica 0 of the only worker hard-fails its
        // first batch run; with retries disabled, that shard's requests
        // must surface explicit errors while siblings stay bit-exact
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                shards: 2,
                dedup: false,
                fault_seed: Some(1),
                fault_rate: 0.0,
                fault_hard_fail_run: Some(0),
                shard_retries: 0,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(200),
                },
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..8)
            .map(|i| Tensor::random(vec![1, 16, 16], 127, 6100 + i))
            .collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        let mut oks = 0usize;
        let mut fails = 0usize;
        for ((id, rx), input) in rxs.into_iter().zip(&inputs) {
            let resp = rx
                .recv()
                .expect("every request gets a response, even on a dead shard");
            assert_eq!(resp.id, id);
            if resp.is_ok() {
                let want = inst.forward_ref(input).unwrap();
                assert_eq!(resp.logits, want.data, "sibling request {id} corrupted");
                oks += 1;
            } else {
                let msg = resp.error.as_deref().unwrap_or("");
                assert!(msg.contains("unserved"), "unexpected error: {msg}");
                fails += 1;
            }
        }
        // exactly one shard run hard-failed: some requests died with it,
        // the rest of the batch was answered normally
        assert!(fails >= 1, "the hard-failed shard must surface errors");
        assert!(oks >= 1, "sibling requests must still be served");
        assert_eq!(oks + fails, 8);
        let stats = coord.shutdown();
        assert_eq!(stats.count(), oks);
        assert_eq!(stats.errors, fails as u64);
        assert_eq!(stats.faults_injected, 1);
        assert_eq!(stats.failovers, 0, "retries were disabled");
    }

    #[test]
    fn coordinator_fails_over_injected_faults_bit_exact() {
        let inst = tiny_instance();
        // same drill with the default retry budget: the faulted shard
        // fails over to a healthy replica and every answer stays bit-exact
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                shards: 2,
                dedup: false,
                fault_seed: Some(1),
                fault_rate: 0.0,
                fault_hard_fail_run: Some(0),
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(200),
                },
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..8)
            .map(|i| Tensor::random(vec![1, 16, 16], 127, 6200 + i))
            .collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        for ((id, rx), input) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().expect("response");
            assert!(resp.is_ok(), "request {id}: {:?}", resp.error);
            let want = inst.forward_ref(input).unwrap();
            assert_eq!(resp.logits, want.data, "request {id} after failover");
        }
        let stats = coord.shutdown();
        assert_eq!(stats.count(), 8);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.faults_injected, 1);
        assert_eq!(stats.failovers, 1, "the dead shard re-ran elsewhere");
        assert!(stats.retries >= 1);
    }

    #[test]
    fn full_queue_sheds_with_explicit_overloaded_responses() {
        let inst = tiny_instance();
        // max_wait far exceeds the submission burst and max_batch exceeds
        // queue_depth, so no batch can form (and free slots) until long
        // after every submission returned: admission is deterministic
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                dedup: false,
                queue_depth: 4,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(300),
                },
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..8)
            .map(|i| Tensor::random(vec![1, 16, 16], 127, 6300 + i))
            .collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        assert_eq!(coord.queued_len(), 4, "the bound admits exactly queue_depth");
        for (i, ((id, rx), input)) in rxs.into_iter().zip(&inputs).enumerate() {
            let resp = rx
                .recv()
                .expect("shed requests get explicit responses, never dropped channels");
            assert_eq!(resp.id, id);
            if i < 4 {
                // admitted: served bit-exact once the batch window closes
                assert!(resp.is_ok(), "admitted request {i}: {:?}", resp.error);
                let want = inst.forward_ref(input).unwrap();
                assert_eq!(resp.logits, want.data);
            } else {
                // shed at the front door
                assert!(!resp.is_ok());
                let msg = resp.error.as_deref().unwrap_or("");
                assert!(msg.contains("overloaded"), "unexpected error: {msg}");
                assert_eq!(resp.accel_cycles, 0);
            }
        }
        let stats = coord.shutdown();
        assert_eq!(stats.shed, 4);
        assert_eq!(stats.count(), 4);
        assert_eq!(stats.errors, 0, "a shed is not a served-then-failed request");
    }

    #[test]
    fn expired_deadlines_fail_before_spending_cycles() {
        let inst = tiny_instance();
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                dedup: false,
                deadline: Some(Duration::ZERO),
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let rxs: Vec<_> = (0..3)
            .map(|i| {
                coord
                    .submit(Tensor::random(vec![1, 16, 16], 127, 6400 + i))
                    .unwrap()
            })
            .collect();
        for (_, rx) in rxs {
            let resp = rx.recv().expect("expired requests still get responses");
            assert!(!resp.is_ok());
            let msg = resp.error.as_deref().unwrap_or("");
            assert!(msg.contains("deadline exceeded"), "unexpected error: {msg}");
        }
        let stats = coord.shutdown();
        assert_eq!(stats.deadline_expired, 3);
        assert_eq!(stats.errors, 3);
        assert_eq!(stats.count(), 0);
        assert_eq!(stats.batches, 0, "no accelerator batch may form");
        assert_eq!(stats.accel_cycles, 0, "expired work must cost no cycles");
    }

    #[test]
    fn shutdown_drains_queued_requests_with_explicit_failures() {
        let inst = tiny_instance();
        // the batch window is far longer than the test: queued requests
        // can only leave the batcher when shutdown closes the front door
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                dedup: false,
                batch: BatchPolicy {
                    max_batch: 64,
                    max_wait: Duration::from_secs(5),
                },
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                coord
                    .submit(Tensor::random(vec![1, 16, 16], 127, 6500 + i))
                    .unwrap()
            })
            .collect();
        let stats = coord.shutdown();
        for (id, rx) in rxs {
            let resp = rx
                .recv()
                .expect("a draining shutdown answers every request — no dropped channels");
            assert_eq!(resp.id, id);
            assert!(!resp.is_ok());
            let msg = resp.error.as_deref().unwrap_or("");
            assert!(msg.contains("shutting down"), "unexpected error: {msg}");
        }
        assert_eq!(stats.count(), 0, "drained requests are not served requests");
    }

    #[test]
    fn serving_survives_a_poisoned_stats_mutex() {
        let inst = tiny_instance();
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        // poison the shared stats mutex the way a panicking thread would
        let stats = Arc::clone(&coord.stats);
        let h = std::thread::spawn(move || {
            let _g = stats.lock().unwrap();
            panic!("induced panic while holding the stats lock");
        });
        assert!(h.join().is_err());
        assert!(coord.stats.lock().is_err(), "mutex must actually be poisoned");
        // the coordinator keeps serving through the poison, bit-exact
        let input = Tensor::random(vec![1, 16, 16], 127, 6600);
        let (_, rx) = coord.submit(input.clone()).unwrap();
        let resp = rx.recv().expect("service continues after an induced panic");
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert_eq!(resp.logits, inst.forward_ref(&input).unwrap().data);
        // metrics and shutdown recover the guard instead of cascading
        assert!(coord.metrics_text().contains("kom_requests_total 1"));
        let stats = coord.shutdown();
        assert_eq!(stats.count(), 1);
    }

    #[test]
    fn continuous_mode_serves_bit_exact_with_queue_wait_telemetry() {
        let inst = tiny_instance();
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                continuous: true,
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let inputs: Vec<Tensor> = (0..12)
            .map(|i| Tensor::random(vec![1, 16, 16], 127, 7700 + i))
            .collect();
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        for ((id, rx), input) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, id);
            assert!(resp.is_ok(), "{:?}", resp.error);
            let want = inst.forward_ref(input).unwrap();
            assert_eq!(resp.logits, want.data, "request {id} under continuous batching");
            assert!(resp.queue_wait_us <= resp.latency_us, "wait is part of latency");
        }
        // the new telemetry surfaces on the metrics page
        let metrics = coord.metrics_text();
        assert!(metrics.contains("kom_batch_size_bucket{le=\"+Inf\"}"));
        assert!(metrics.contains("kom_queue_wait_us{quantile=\"0.99\"}"));
        let stats = coord.shutdown();
        assert_eq!(stats.count(), 12);
        let (_, _, dispatches) = stats.batch_size_histogram();
        assert!(dispatches >= 1, "every dispatch logs its chosen size");
        assert!(stats.queue_wait().count >= 12, "every rider logs its wait");
    }

    #[test]
    fn continuous_unattainable_slo_sheds_after_warmup() {
        let inst = tiny_instance();
        // a 1us p99 target is hopeless for Tiny (thousands of cycles per
        // request), but the cold EMA of 1 cycle rounds to 0us — so the
        // first request is admitted, teaches the scheduler the real cost,
        // and everything after it sheds at the front door
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                dedup: false,
                continuous: true,
                slo_p99_us: Some(1),
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let first = Tensor::random(vec![1, 16, 16], 127, 7800);
        let (_, rx) = coord.submit(first.clone()).unwrap();
        let resp = rx.recv().expect("cold request served");
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert_eq!(resp.logits, inst.forward_ref(&first).unwrap().data);
        // the EMA is published before the response goes out, so these
        // submissions deterministically see the learned cost
        for i in 0..3 {
            let (_, rx) = coord
                .submit(Tensor::random(vec![1, 16, 16], 127, 7810 + i))
                .unwrap();
            let resp = rx.recv().expect("shed requests get explicit responses");
            assert!(!resp.is_ok());
            let msg = resp.error.as_deref().unwrap_or("");
            assert!(msg.contains("overloaded"), "unexpected error: {msg}");
            assert!(msg.contains("unattainable"), "unexpected error: {msg}");
            assert_eq!(resp.accel_cycles, 0, "a shed costs no cycles");
        }
        let stats = coord.shutdown();
        assert_eq!(stats.count(), 1, "only the warmup request was served");
        assert_eq!(stats.shed, 3);
        assert_eq!(stats.errors, 0, "a shed is not a served-then-failed request");
    }

    #[test]
    fn zero_shards_rejected() {
        let inst = tiny_instance();
        assert!(Coordinator::start(
            CoordinatorConfig {
                shards: 0,
                ..Default::default()
            },
            &inst
        )
        .is_err());
    }

    #[test]
    fn zero_workers_rejected() {
        let inst = tiny_instance();
        assert!(Coordinator::start(
            CoordinatorConfig {
                workers: 0,
                ..Default::default()
            },
            &inst
        )
        .is_err());
    }
}
