//! `kom-accel` — leader entrypoint / CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §5):
//!
//! ```text
//! kom-accel tables  [--n 3|5|7|11] [--full]                 Tables 1–4
//! kom-accel timing                                          Table 5 (delay+power)
//! kom-accel emit    --mult kom32 [--out file.v] [--dot]     Fig 4 (RTL)
//! kom-accel wave    [--out kom32.vcd]                       Fig 5 (waveform)
//! kom-accel analyze [--net alexnet|vgg16|vgg19]             §V network analysis
//! kom-accel golden  [--artifacts dir]                       3-way golden check
//! kom-accel serve   [--requests 64] [--workers 2]           coordinator demo
//! kom-accel cluster [--batch 16] [--shards 4]               sharded multi-SoC run
//! kom-accel lint    [--net tiny] [--batch 8]                static plan verifier
//! kom-accel trace   [--net tiny] [--batch 8] [--shards 2]   Perfetto trace export
//! kom-accel loadgen [--rate-rps N] [--continuous]           simulated-time SLO bench
//! ```

use kom_accel::accel::{
    verify, Driver, FaultConfig, FaultPlan, LayerCycles, LayerDesc, RunTrace, Severity,
    ShardedMetrics, SocConfig, SpanKind, DEFAULT_RING_CAPACITY,
};
use kom_accel::bits::BitVec;
use kom_accel::cli::Args;
use kom_accel::cluster::{Cluster, ClusterConfig, SchedulePolicy, Scheduler};
use kom_accel::cnn::networks::{Network, NetworkInstance, NetworkKind, DEFAULT_SHARD_RETRIES};
use kom_accel::cnn::{analysis, Tensor};
use kom_accel::coordinator::{
    probe_us_per_req, run_loadgen, Arrivals, BatchMode, Coordinator, CoordinatorConfig,
    DedupCache, LoadGenConfig, StatsCollector,
};
use kom_accel::multipliers::{generate, MultKind, MultiplierSpec};
use kom_accel::report::Table;
use kom_accel::runtime::{golden, ArtifactStore};
use kom_accel::{matrix, power, sim, sta, techmap};
use std::path::Path;

const USAGE: &str = "\
kom-accel — FPGA CNN accelerator with Karatsuba-Ofman multipliers

USAGE: kom-accel <command> [flags]

COMMANDS
  tables   [--n 3] [--full]          resource tables (paper Tables 1-4)
  timing                             delay + power (paper Table 5)
  emit     --mult <kom16|kom32|bw32|dadda32> [--out f.v] [--dot]
  wave     [--out kom32.vcd]         gate-level waveform (paper Fig 5)
  analyze  [--net alexnet]           network analysis (paper Sec V)
  golden   [--artifacts artifacts]   XLA vs systolic vs reference
  serve    [--requests 64] [--workers 2] [--batch 8] [--shards 1] [--no-pipeline]
           [--no-fuse] [--no-dedup] [--dedup-budget W] [--no-config-cache]
           [--metrics-interval N] [--queue-depth N] [--deadline-us N]
           [--fault-seed S] [--fault-rate P] [--continuous] [--slo-p99-us N]
  cluster  [--batch 16] [--shards 4] [--policy rr|least-outstanding] [--net tiny]
           [--no-pipeline] [--no-fuse] [--no-config-cache]
           [--fault-seed S] [--fault-rate P]
  lint     [--net tiny] [--batch 8] [--shards 1] [--no-fuse] [--deny-warnings]
  trace    [--net tiny] [--batch 8] [--shards 2] [--out trace.json]
           [--no-pipeline] [--no-fuse] [--no-config-cache]
  loadgen  [--requests 128] [--batch 16] [--shards 4] [--seed S]
           [--rate-rps N | --closed C [--think-us N] | --burst B [--period-us N]]
           [--continuous] [--slo-p99-us N] [--max-wait-us N]

Pipelining: replica SoCs overlap layer DMA with engine compute by default
(double-buffered scratchpad staging); --no-pipeline restores the serial
cpu + compute + mem cycle model.
Fusion: chained layers whose intermediate activations fit the scratchpad
skip the DRAM store + reload entirely (whole-buffer or row-band-tiled
residency) by default; --no-fuse restores the per-layer round trip.
Compiled plans: descriptor tables compile once into cached execution
plans, and warm runs skip every per-layer engine reconfiguration through
the configuration-context cache; --no-config-cache restores the cold
reconfiguration model. --no-dedup disables the front-door exact-input
result cache; --dedup-budget W bounds it to W resident words (default
holds 1024 Tiny-sized entries).
Lint: deploy the network's descriptor table exactly as serving would,
then run the static plan verifier over it (region aliasing, dataflow
chaining, fusion-binding soundness, encoding round-trip, cycle-model
sanity) without executing a single layer. Exit 1 on any KOM-Exxx error,
or on KOM-Wxxx warnings under --deny-warnings.
Trace: run one cold + one warm sharded batch with the execution tracer
armed, check the conservation identities (per-layer span sums must equal
every shard's RunMetrics components exactly), and write a Perfetto /
chrome://tracing JSON — one track per shard, nested layer spans. serve's
--metrics-interval N prints the Prometheus-style metrics page every N
completed responses (0 = off); serve and cluster both end with a
per-layer cycle-hotspots table from the aggregated trace.
Robustness: --queue-depth N bounds serve's admission queue (excess
submissions are shed with explicit overloaded failures); --deadline-us N
fails requests that waited longer than N microseconds before the
accelerator batch forms (0 = no deadline). --fault-seed S arms a
deterministic seeded fault plan on replica 0 (DMA transfer errors,
weight-load corruption, stuck replicas) at per-site probability
--fault-rate P; faulted shards retry on healthy replicas, the faulty
replica is quarantined and re-admitted after a health probe, and every
served answer must stay bit-exact with the host reference.
Continuous batching: serve's --continuous replaces the fixed
fill-to-max/timeout batcher with worker-driven admission — a free worker
takes whatever is queued immediately, sized against --slo-p99-us N (the
p99 latency target in microseconds, 0 = no target) using the scheduler's
measured cycles/request; unattainable targets shed at the front door
with explicit overloaded failures. loadgen drives the same cluster
through a simulated-time arrival process (open-loop Poisson --rate-rps,
closed-loop --closed C clients with --think-us, or --burst B every
--period-us) in either batching mode and prints the latency
distribution; every response is checked bit-exact against the host
reference.
";

/// Optional numeric flag: absent → `None`, present → parsed or a usage
/// error (the `Args::get_num` default-value shape can't express "unset").
fn opt_num<T: std::str::FromStr>(args: &Args, key: &str) -> kom_accel::Result<Option<T>> {
    match args.get(key) {
        None => Ok(None),
        Some(v) => v.parse().map(Some).map_err(|_| {
            kom_accel::Error::Usage(format!("--{key} expects a number, got '{v}'"))
        }),
    }
}

fn mult_spec(name: &str) -> kom_accel::Result<(String, MultiplierSpec)> {
    Ok(match name {
        "kom16" => ("16-bit KOM".into(), MultiplierSpec::pipelined(MultKind::KaratsubaOfman, 16, 3)),
        "kom32" => ("32-bit KOM".into(), MultiplierSpec::pipelined(MultKind::KaratsubaOfman, 32, 4)),
        "bw32" => ("32-bit Baugh-Wooley".into(), MultiplierSpec::comb_regio(MultKind::BaughWooley, 32)),
        "dadda32" => ("32-bit Dadda".into(), MultiplierSpec::comb(MultKind::Dadda, 32)),
        other => {
            let kind = MultKind::parse(other)?;
            (other.to_string(), MultiplierSpec::comb(kind, 32))
        }
    })
}

fn cmd_tables(args: &Args) -> kom_accel::Result<()> {
    let n: u32 = args.get_num("n", 3u32)?;
    let full = args.has("full");
    println!("Table: {n}x{n} x {n}x{n} matrix multiplication ({} multipliers)\n", n.pow(3));
    let mut t = Table::new(&["Logic utilization", "16-bit KOM", "32-bit KOM", "32-bit Baugh-Wooley", "32-bit Dadda"]);
    let mut cols = Vec::new();
    for (_, spec) in MultiplierSpec::paper_set() {
        let r = matrix::analyze(n, spec)?;
        cols.push(if full { r.full } else { r.paper });
    }
    for (i, metric) in ["No of slice registers", "No of slice LUT", "No of fully used LUT FF pairs", "No of bonded IOBs"].iter().enumerate() {
        let mut row = vec![metric.to_string()];
        for c in &cols {
            let v = c.paper_rows()[i].1;
            row.push(v.to_string());
        }
        t.row(row);
    }
    println!("{}", t.to_ascii());
    Ok(())
}

fn cmd_timing() -> kom_accel::Result<()> {
    let mut t = Table::new(&["Parameter", "KOM (32 bit)", "KOM (16 bit)", "Baugh-Wooley (32)", "Dadda (32)"]);
    let order = ["kom32", "kom16", "bw32", "dadda32"];
    let mut delays = Vec::new();
    let mut powers = Vec::new();
    for key in order {
        let (_, spec) = mult_spec(key)?;
        let g = generate(spec)?;
        let mapped = techmap::map(&g.netlist)?;
        let timing = sta::analyze(&mapped);
        let f = timing.fmax_mhz.map(|m| m * 1e6).unwrap_or(100e6);
        let p = power::estimate(&mapped, f, 200)?;
        delays.push(format!("{:.3}ns", timing.critical_path_ns));
        powers.push(format!("{:.2} mW", p.total_mw()));
    }
    t.row(std::iter::once("TIME DELAY".to_string()).chain(delays).collect());
    t.row(std::iter::once("POWER DISSIPATION".to_string()).chain(powers).collect());
    println!("{}", t.to_ascii());
    println!("(paper Table 5: 4.604ns / 4.052ns / 15.415ns / 47.500ns; 90.37mW / 85.14mW / - / -)");
    Ok(())
}

fn cmd_emit(args: &Args) -> kom_accel::Result<()> {
    let name = args.require("mult")?;
    let (label, spec) = mult_spec(name)?;
    let g = generate(spec)?;
    let text = if args.has("dot") {
        kom_accel::netlist::to_dot(&g.netlist)
    } else {
        kom_accel::netlist::to_verilog(&g.netlist)
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("wrote {label} ({} nets) to {path}", g.netlist.num_nets());
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_wave(args: &Args) -> kom_accel::Result<()> {
    let out = args.get_or("out", "kom32.vcd");
    let g = generate(MultiplierSpec::pipelined(MultKind::KaratsubaOfman, 32, 4))?;
    let nl = &g.netlist;
    let mut es = sim::EventSim::new(nl)?;
    let a_bus = nl.inputs()["a"].clone();
    let b_bus = nl.inputs()["b"].clone();
    let p_bus = nl.outputs()["p"].clone();
    let stimulus: Vec<Vec<(kom_accel::netlist::Bus, BitVec)>> = (0..24u64)
        .map(|i| {
            let a = 0x1234_5678u64.wrapping_mul(i + 1) as u32;
            let b = 0x9abc_def0u64.wrapping_mul(i + 3) as u32;
            vec![
                (a_bus.clone(), BitVec::from_u128(a as u128, 32)),
                (b_bus.clone(), BitVec::from_u128(b as u128, 32)),
            ]
        })
        .collect();
    let file = std::fs::File::create(&out)?;
    es.run_clocked_vcd(
        5000, // 5ns clock (200 MHz)
        &stimulus,
        &[("a", a_bus), ("b", b_bus), ("p", p_bus)],
        std::io::BufWriter::new(file),
    )?;
    println!("wrote {out} ({} cycles, {} gate evals)", stimulus.len(), es.evals);
    Ok(())
}

fn cmd_analyze(args: &Args) -> kom_accel::Result<()> {
    let kinds: Vec<NetworkKind> = match args.get("net") {
        Some(n) => vec![NetworkKind::parse(n)?],
        None => vec![NetworkKind::AlexNet, NetworkKind::Vgg16, NetworkKind::Vgg19],
    };
    for kind in kinds {
        let net = Network::build(kind);
        println!("\n=== {} ===", net.name);
        println!("  weights: {:.1} M", net.total_weights()? as f64 / 1e6);
        println!("  MACs/inference: {:.2} G", net.total_macs()? as f64 / 1e9);
        let fh = analysis::filter_histogram(&net);
        for (k, count) in &fh {
            println!("  {k}x{k} filters: {count}");
        }
        let (_, spec) = mult_spec("kom16")?;
        let r = analysis::network_resources(&net, spec)?;
        println!("  matrix-unit model (16-bit KOM):");
        for (k, (count, rep)) in &r.per_kernel {
            println!("    k={k}: {count} kernel matrices, unit = {rep}");
        }
        println!("  time-multiplexed engine total: {}", r.total_multiplexed);
        println!("  worst unit critical path: {:.2} ns", r.worst_cp_ns);
    }
    Ok(())
}

fn cmd_golden(args: &Args) -> kom_accel::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let store = ArtifactStore::open(Path::new(&dir))?;
    let report = golden::run_tiny_golden(&store, 42, 7)?;
    println!("reference: {:?}", report.reference);
    println!("systolic : {:?}", report.systolic);
    println!("xla      : {:?}", report.xla);
    println!("accelerator cycles: {}", report.metrics.total_cycles());
    if report.consistent() {
        println!("GOLDEN OK — all three layers agree bit-exactly");
        Ok(())
    } else {
        Err(kom_accel::Error::Runtime("golden mismatch".into()))
    }
}

fn cmd_serve(args: &Args) -> kom_accel::Result<()> {
    let requests: usize = args.get_num("requests", 64usize)?;
    let workers: usize = args.get_num("workers", 2usize)?;
    let max_batch: usize = args.get_num("batch", 8usize)?;
    let shards: usize = args.get_num("shards", 1usize)?;
    let pipeline = !args.has("no-pipeline");
    let fuse = !args.has("no-fuse");
    let dedup = !args.has("no-dedup");
    let dedup_budget_words: usize =
        args.get_num("dedup-budget", DedupCache::DEFAULT_BUDGET_WORDS)?;
    let config_cache = !args.has("no-config-cache");
    let metrics_interval: usize = args.get_num("metrics-interval", 0usize)?;
    let queue_depth: usize = args.get_num("queue-depth", 0usize)?;
    let deadline_us: u64 = args.get_num("deadline-us", 0u64)?;
    let fault_seed: Option<u64> = opt_num(args, "fault-seed")?;
    let fault_rate: f64 = args.get_num("fault-rate", 0.0f64)?;
    let continuous = args.has("continuous");
    let slo_p99_us: u64 = args.get_num("slo-p99-us", 0u64)?;
    let inst = NetworkInstance::random(Network::build(NetworkKind::Tiny), 42)?;
    let cfg = CoordinatorConfig {
        workers,
        shards,
        pipeline,
        fuse,
        dedup,
        dedup_budget_words,
        config_cache,
        queue_depth,
        continuous,
        slo_p99_us: (slo_p99_us > 0).then_some(slo_p99_us),
        deadline: (deadline_us > 0).then(|| std::time::Duration::from_micros(deadline_us)),
        fault_seed,
        fault_rate,
        // the demo always traces so it can close with the per-layer
        // hotspots table (serving defaults keep tracing off)
        trace: true,
        batch: kom_accel::coordinator::BatchPolicy {
            max_batch,
            ..Default::default()
        },
        soc: SocConfig::serving(),
        ..Default::default()
    };
    let coord = Coordinator::start(cfg, &inst)?;
    let rxs: Vec<_> = (0..requests)
        .map(|i| coord.submit(Tensor::random(vec![1, 16, 16], 127, i as u64 + 1)).unwrap())
        .collect();
    for (i, (_, rx)) in rxs.into_iter().enumerate() {
        rx.recv().map_err(|_| kom_accel::Error::Coordinator("lost response".into()))?;
        if metrics_interval > 0 && (i + 1) % metrics_interval == 0 {
            println!("--- metrics after {} responses ---", i + 1);
            print!("{}", coord.metrics_text());
        }
    }
    let stats = coord.shutdown();
    let l = stats.latency();
    println!(
        "served {requests} requests on {workers} workers (max batch {max_batch}, {shards} \
         shard(s)/worker, pipelining {}, fusion {}, {} batching)",
        if pipeline { "on" } else { "off" },
        if fuse { "on" } else { "off" },
        if continuous { "continuous" } else { "fixed" }
    );
    println!("  host latency: p50={}us p95={}us p99={}us max={}us", l.p50_us, l.p95_us, l.p99_us, l.max_us);
    let qw = stats.queue_wait();
    if qw.count > 0 {
        println!("  queue wait: p50={}us p99={}us max={}us", qw.p50_us, qw.p99_us, qw.max_us);
    }
    println!("  mean batch: {:.2}", stats.mean_batch());
    println!("  simulated accel cycles: {}", stats.accel_cycles);
    if pipeline {
        println!(
            "  DMA cycles hidden under compute: {} ({:.0}% of serial traffic+compute charge)",
            stats.overlapped_cycles,
            stats.overlap_fraction() * 100.0
        );
    }
    if fuse {
        println!(
            "  DMA cycles eliminated by layer fusion: {} ({:.0}% of the unfused charge)",
            stats.fused_saved_cycles,
            stats.fused_fraction() * 100.0
        );
    }
    println!(
        "  plan-cache hit rate: {:.0}% over {} shard runs",
        stats.plan_cache_hit_rate() * 100.0,
        stats.plan_runs
    );
    if config_cache {
        println!(
            "  engine reconfigurations: {} performed, {} skipped warm",
            stats.reconfigs, stats.reconfigs_skipped
        );
    }
    if dedup {
        println!("  front-door dedup hits: {}", stats.dedup_hits);
    }
    if queue_depth > 0 || stats.shed > 0 || stats.deadline_expired > 0 {
        println!(
            "  shed at front door: {} (queue depth {queue_depth}); deadline-expired: {}",
            stats.shed, stats.deadline_expired
        );
    }
    if fault_seed.is_some() {
        println!(
            "  faults injected: {} → {} retries, {} failover(s), {} request error(s)",
            stats.faults_injected, stats.retries, stats.failovers, stats.errors
        );
    }
    if shards > 1 {
        let util: Vec<String> = stats
            .shard_utilization()
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect();
        println!("  per-shard utilization: [{}]", util.join(", "));
        println!("  amortized cycles/req: {:.0}", stats.amortized_cycles_per_request());
    }
    let hot = stats.hotspots(5);
    if !hot.is_empty() {
        println!("  per-layer cycle hotspots (top {}):", hot.len());
        println!("{}", hotspot_table(&hot));
    }
    Ok(())
}

/// `loadgen`: drive a real cluster through the simulated-time load
/// generator and print the latency distribution — the CLI face of the
/// `BENCH_slo.json` bench section.
fn cmd_loadgen(args: &Args) -> kom_accel::Result<()> {
    let requests: usize = args.get_num("requests", 128usize)?;
    let max_batch: usize = args.get_num("batch", 16usize)?;
    let shards: usize = args.get_num("shards", 4usize)?;
    let seed: u64 = args.get_num("seed", 42_000u64)?;
    let slo_p99_us: u64 = args.get_num("slo-p99-us", 0u64)?;
    let continuous = args.has("continuous");
    let clock_mhz = 200.0;
    let inst = NetworkInstance::random(Network::build(NetworkKind::Tiny), 42)?;
    // measure the warm cost first so rate/wait defaults track the
    // hardware instead of hard-coding microseconds
    let e = probe_us_per_req(&inst, shards, max_batch, clock_mhz)?;
    let capacity_rps = shards as f64 * 1e6 / e as f64;
    let arrivals = if let Some(concurrency) = opt_num::<usize>(args, "closed")? {
        Arrivals::Closed {
            concurrency,
            think_us: args.get_num("think-us", 0u64)?,
        }
    } else if let Some(burst) = opt_num::<usize>(args, "burst")? {
        Arrivals::Bursts {
            burst,
            period_us: args.get_num("period-us", 8 * e.max(1))?,
        }
    } else {
        Arrivals::Poisson {
            rate_rps: args.get_num("rate-rps", capacity_rps * 0.5)?,
            seed: 11,
        }
    };
    let mode = if continuous {
        BatchMode::Continuous
    } else {
        BatchMode::Fixed {
            max_wait_us: args.get_num("max-wait-us", 2 * e.max(1))?,
        }
    };
    println!(
        "loadgen: {requests} requests, {arrivals:?}, {mode:?}, {shards} shard(s), \
         batch {max_batch} (warm cost {e} us/req, capacity {capacity_rps:.0} req/s)"
    );
    let r = run_loadgen(
        &inst,
        &LoadGenConfig {
            arrivals,
            mode,
            requests,
            max_batch,
            shards,
            clock_mhz,
            slo_p99_us: (slo_p99_us > 0).then_some(slo_p99_us),
            seed,
            warmup: true,
        },
    )?;
    println!(
        "  served {} / shed {} in {} simulated us ({:.0} req/s)",
        r.served, r.shed, r.makespan_us, r.throughput_rps
    );
    println!(
        "  latency: p50={}us p95={}us p99={}us max={}us mean={:.0}us",
        r.p50_us, r.p95_us, r.p99_us, r.max_us, r.mean_us
    );
    println!(
        "  batches: {} (mean {:.2}, max {}); learned cost {} us/req",
        r.batches, r.mean_batch, r.max_batch_size, r.ema_us_per_req
    );
    if r.mismatches > 0 {
        return Err(kom_accel::Error::Coordinator(format!(
            "{} response(s) diverged from forward_ref",
            r.mismatches
        )));
    }
    println!("  every served response bit-exact vs forward_ref");
    Ok(())
}

/// Render the per-layer "cycle hotspots" table: where the timeline cycles
/// went (compute vs reconfiguration vs DMA), what pipelining hid and what
/// fusion skipped outright, ranked by timeline share.
fn hotspot_table(rows: &[(usize, LayerCycles)]) -> String {
    let mut t = Table::new(&[
        "layer",
        "compute",
        "reconf",
        "dma-in",
        "dma-out",
        "weights",
        "hidden",
        "fused-skip",
        "busy",
    ]);
    for (layer, r) in rows {
        t.row(vec![
            layer.to_string(),
            r.compute.to_string(),
            r.reconfig.to_string(),
            r.dma_in.to_string(),
            r.dma_out.to_string(),
            r.weight_load.to_string(),
            r.overlapped.to_string(),
            r.fused_saved.to_string(),
            r.busy().to_string(),
        ]);
    }
    t.to_ascii()
}

/// Check the trace against every shard's metrics: the conservation
/// identities must hold exactly — the trace is the cycle model's ledger,
/// not a parallel estimate (see `accel::trace`).
fn check_trace_conservation(trace: &RunTrace, m: &ShardedMetrics) -> kom_accel::Result<()> {
    if trace.dropped > 0 {
        return Err(kom_accel::Error::Runtime(format!(
            "trace ring overflowed: {} span(s) dropped — raise the ring capacity",
            trace.dropped
        )));
    }
    for run in &m.shards {
        let shard = run.shard as u32;
        let sum = |k: SpanKind| -> u64 {
            trace
                .events
                .iter()
                .filter(|e| e.shard == shard && e.kind == k)
                .map(|e| e.cycles)
                .sum()
        };
        let compute = sum(SpanKind::Compute) + sum(SpanKind::Reconfig);
        let mem = sum(SpanKind::DmaIn) + sum(SpanKind::WeightLoad) + sum(SpanKind::DmaOut);
        // the driver clamps each run's overlap credit to the smaller of
        // the windows it can hide under (a drain window may span runs)
        let overlapped = sum(SpanKind::OverlapCredit).min(compute).min(mem);
        let fused = sum(SpanKind::FusionSkip);
        let mm = &run.metrics;
        if compute != mm.compute_cycles
            || mem != mm.mem_cycles
            || overlapped != mm.overlapped_cycles
            || fused != mm.fused_saved_cycles
        {
            return Err(kom_accel::Error::Runtime(format!(
                "shard {shard}: trace does not conserve metrics (compute {compute} vs {}, \
                 mem {mem} vs {}, overlapped {overlapped} vs {}, fused {fused} vs {})",
                mm.compute_cycles, mm.mem_cycles, mm.overlapped_cycles, mm.fused_saved_cycles
            )));
        }
    }
    Ok(())
}

/// Trace one cold + one warm sharded run with the execution tracer armed,
/// verify the conservation identities against each dispatch's metrics,
/// and export both runs as one Perfetto / chrome://tracing JSON file.
fn cmd_trace(args: &Args) -> kom_accel::Result<()> {
    let batch: usize = args.get_num("batch", 8usize)?;
    let shards: usize = args.get_num("shards", 2usize)?;
    let out = args.get_or("out", "trace.json");
    let pipeline = !args.has("no-pipeline");
    let fuse = !args.has("no-fuse");
    let config_cache = !args.has("no-config-cache");
    let kind = NetworkKind::parse(&args.get_or("net", "tiny"))?;
    let inst = NetworkInstance::random(Network::build(kind), 42)?;
    let inputs: Vec<Tensor> = (0..batch)
        .map(|i| Tensor::random(inst.net.input.dims(), 127, i as u64 + 1))
        .collect();

    let mut cluster = Cluster::new(ClusterConfig {
        replicas: shards,
        soc: SocConfig::serving(),
    })?;
    cluster.set_pipeline(pipeline)?;
    cluster.set_fusion(fuse);
    cluster.set_config_cache(config_cache);
    cluster.set_tracing(DEFAULT_RING_CAPACITY);
    let per_shard_cap = batch.div_ceil(shards);
    let cdep = inst.deploy_cluster(&mut cluster, per_shard_cap)?;
    let mut sched = Scheduler::new(SchedulePolicy::LeastOutstandingCycles, shards)?;
    let slices: Vec<&[i64]> = inputs.iter().map(|t| t.data.as_slice()).collect();

    // cold dispatch (plan compiles + engine configuration), then warm —
    // each verified against its own dispatch's metrics before the two
    // are laid out sequentially on the exported timeline
    let (_, cold_m) = cdep.run_sharded(&mut cluster, &mut sched, &slices)?;
    let mut trace = cluster.take_stitched_trace(&cold_m);
    check_trace_conservation(&trace, &cold_m)?;
    let (_, warm_m) = cdep.run_sharded(&mut cluster, &mut sched, &slices)?;
    let warm = cluster.take_stitched_trace(&warm_m);
    check_trace_conservation(&warm, &warm_m)?;
    trace.absorb(warm);

    std::fs::write(&out, trace.to_chrome_trace())?;
    println!(
        "{}: traced cold + warm batch of {batch} over {shards} shard(s) \
         (pipelining {}, fusion {}, config cache {})",
        inst.net.name,
        if pipeline { "on" } else { "off" },
        if fuse { "on" } else { "off" },
        if config_cache { "on" } else { "off" }
    );
    println!(
        "conservation OK: span sums equal RunMetrics components on every shard of both runs"
    );
    let mut sc = StatsCollector::new();
    sc.record_trace(&trace);
    println!("per-layer cycle hotspots (top {}):", sc.hotspots(5).len());
    println!("{}", hotspot_table(&sc.hotspots(5)));
    println!(
        "wrote {out} ({} spans, {} plan compiles marked) — load in ui.perfetto.dev \
         or chrome://tracing",
        trace.events.len(),
        trace.kind_count(SpanKind::PlanCompile)
    );
    Ok(())
}

/// Run one sharded Tiny-network batch across a multi-SoC cluster and print
/// the per-shard cycle table — the cluster subsystem drivable from the CLI.
fn cmd_cluster(args: &Args) -> kom_accel::Result<()> {
    let batch: usize = args.get_num("batch", 16usize)?;
    let shards: usize = args.get_num("shards", 4usize)?;
    let pipeline = !args.has("no-pipeline");
    let fuse = !args.has("no-fuse");
    let config_cache = !args.has("no-config-cache");
    let policy = SchedulePolicy::parse(&args.get_or("policy", "least-outstanding"))?;
    let kind = NetworkKind::parse(&args.get_or("net", "tiny"))?;
    let fault_seed: Option<u64> = opt_num(args, "fault-seed")?;
    let fault_rate: f64 = args.get_num("fault-rate", 0.05f64)?;
    let inst = NetworkInstance::random(Network::build(kind), 42)?;
    let inputs: Vec<Tensor> = (0..batch)
        .map(|i| Tensor::random(inst.net.input.dims(), 127, i as u64 + 1))
        .collect();

    let mut cluster = Cluster::new(ClusterConfig {
        replicas: shards,
        soc: SocConfig::serving(),
    })?;
    cluster.set_pipeline(pipeline)?;
    cluster.set_fusion(fuse);
    cluster.set_config_cache(config_cache);
    cluster.set_tracing(DEFAULT_RING_CAPACITY);
    // the fault drill must survive one quarantined replica: deploy enough
    // per-replica capacity for the remaining shards to absorb the batch
    let per_shard_cap = if fault_seed.is_some() && shards > 1 {
        batch.div_ceil(shards - 1)
    } else {
        batch.div_ceil(shards)
    };
    let cdep = inst.deploy_cluster(&mut cluster, per_shard_cap)?;
    let mut sched = Scheduler::new(policy, shards)?;
    let slices: Vec<&[i64]> = inputs.iter().map(|t| t.data.as_slice()).collect();

    if let Some(seed) = fault_seed {
        // fault drill: arm a deterministic plan on replica 0 and run both
        // dispatches through the degraded path — served answers must stay
        // bit-exact, failures must be explicit, and the command exits 0
        // as long as nothing is silently corrupted
        cluster.set_fault_plan(
            0,
            Some(FaultPlan::new(FaultConfig {
                seed,
                rate: fault_rate,
                ..Default::default()
            })),
        );
        println!(
            "{}: fault drill — batch {batch} over {shards} shard(s), seed {seed}, rate {fault_rate}",
            inst.net.name
        );
        let mut served = 0usize;
        let mut failed = 0usize;
        let (mut retries, mut failovers, mut quarantined) = (0u64, 0u64, 0u64);
        for pass in ["cold", "warm"] {
            let (outs, m) =
                cdep.run_sharded_degraded(&mut cluster, &mut sched, &slices, DEFAULT_SHARD_RETRIES)?;
            for (i, out) in outs.iter().enumerate() {
                match out {
                    Ok(data) => {
                        let want = inst.forward_ref(&inputs[i])?;
                        if *data != want.data {
                            return Err(kom_accel::Error::Cluster(format!(
                                "request {i} diverged from forward_ref under fault injection \
                                 ({pass} pass)"
                            )));
                        }
                        served += 1;
                    }
                    Err(e) => {
                        println!("  {pass}: request {i} failed explicitly: {e}");
                        failed += 1;
                    }
                }
            }
            retries += m.retries;
            failovers += m.failovers;
            quarantined += m.quarantined;
            println!(
                "  {pass}: {} cycles (max over shards), {} shard run(s)",
                m.total_cycles(),
                m.shards.len()
            );
        }
        println!(
            "fault drill complete: {served} served bit-exact, {failed} explicit failure(s), \
             {} fault(s) injected, {retries} retries, {failovers} failover(s), \
             {quarantined} quarantine(s)",
            cluster.faults_injected()
        );
        println!("no silent corruption: every served request matched forward_ref");
        return Ok(());
    }

    // cold dispatch compiles the plans and loads the engine contexts; the
    // warm dispatch is the steady serving state the table below reports
    let (_, cold_m) = cdep.run_sharded(&mut cluster, &mut sched, &slices)?;
    // drain the cold spans so the hotspots table shows the warm state
    let _ = cluster.take_stitched_trace(&cold_m);
    let (outs, m) = cdep.run_sharded(&mut cluster, &mut sched, &slices)?;
    let warm_trace = cluster.take_stitched_trace(&m);

    // per-request correctness against the host reference
    for (i, t) in inputs.iter().enumerate() {
        let want = inst.forward_ref(t)?;
        if outs[i] != want.data {
            return Err(kom_accel::Error::Cluster(format!(
                "request {i} diverged from forward_ref"
            )));
        }
    }

    println!(
        "{}: batch {batch} over {shards} shard(s), policy {policy:?}, pipelining {}, fusion {}, \
         config cache {}",
        inst.net.name,
        if pipeline { "on" } else { "off" },
        if fuse { "on" } else { "off" },
        if config_cache { "on" } else { "off" }
    );
    let mut t = Table::new(&[
        "shard",
        "replica",
        "requests",
        "cpu",
        "compute",
        "mem",
        "overlapped",
        "fused-saved",
        "reconf",
        "reconf-skip",
        "total cycles",
    ]);
    for run in &m.shards {
        t.row(vec![
            run.shard.to_string(),
            run.replica.to_string(),
            run.metrics.requests.to_string(),
            run.metrics.cpu_cycles.to_string(),
            run.metrics.compute_cycles.to_string(),
            run.metrics.mem_cycles.to_string(),
            run.metrics.overlapped_cycles.to_string(),
            run.metrics.fused_saved_cycles.to_string(),
            run.metrics.reconfigs.to_string(),
            run.metrics.reconfigs_skipped.to_string(),
            run.metrics.total_cycles().to_string(),
        ]);
    }
    println!("{}", t.to_ascii());
    if fuse {
        println!(
            "fused-saved cycles (sum over shards): {}",
            m.fused_saved_cycles()
        );
    }
    println!(
        "cold dispatch (compiles + configures): {} cycles; warm: {} ({:.2}x)",
        cold_m.total_cycles(),
        m.total_cycles(),
        cold_m.total_cycles() as f64 / m.total_cycles().max(1) as f64
    );
    println!(
        "warm reconfigurations skipped (sum over shards): {}; plan hits {}/{}",
        m.reconfigs_skipped(),
        m.plan_hits(),
        m.shards.len()
    );
    let (hits, compiles) = cluster.plan_cache_stats();
    println!(
        "plan-cache hit rate across replicas: {:.0}% ({hits} hits / {compiles} compiles)",
        hits as f64 / (hits + compiles).max(1) as f64 * 100.0
    );
    println!("cluster cycles (max over shards): {}", m.total_cycles());
    println!("serial sum over shards:           {}", m.serial_cycles());
    println!("parallel speedup:                 {:.2}x", m.parallel_speedup());
    let mut sc = StatsCollector::new();
    sc.record_trace(&warm_trace);
    let hot = sc.hotspots(5);
    if !hot.is_empty() {
        println!("warm-run per-layer cycle hotspots (top {}):", hot.len());
        println!("{}", hotspot_table(&hot));
    }

    // single-SoC baseline: the same batch through one replica, equally
    // warmed (one cold dispatch first) so the speedup is like for like
    let mut base = Cluster::new(ClusterConfig {
        replicas: 1,
        soc: SocConfig::serving(),
    })?;
    base.set_pipeline(pipeline)?;
    base.set_fusion(fuse);
    base.set_config_cache(config_cache);
    let base_dep = inst.deploy_cluster(&mut base, batch)?;
    let mut base_sched = Scheduler::new(policy, 1)?;
    base_dep.run_sharded(&mut base, &mut base_sched, &slices)?;
    let (_, bm) = base_dep.run_sharded(&mut base, &mut base_sched, &slices)?;
    println!(
        "single-SoC baseline (warm): {} cycles → sharded speedup {:.2}x",
        bm.total_cycles(),
        bm.total_cycles() as f64 / m.total_cycles().max(1) as f64
    );
    println!("all {batch} requests bit-exact with forward_ref");
    Ok(())
}

/// Statically verify a deployed descriptor table without executing it:
/// deploy the chosen network at the per-shard batch exactly the way
/// `serve`/`cluster` would, run [`Driver::lint_table`], print every
/// diagnostic plus the per-layer cycle lower bounds, and set the exit
/// status for CI (`1` on errors, or on warnings under `--deny-warnings`).
fn cmd_lint(args: &Args) -> kom_accel::Result<()> {
    let kind = NetworkKind::parse(&args.get_or("net", "tiny"))?;
    let batch: usize = args.get_num("batch", 8usize)?;
    let shards: usize = args.get_num("shards", 1usize)?;
    let fuse = !args.has("no-fuse");
    let deny_warnings = args.has("deny-warnings");
    if batch == 0 || shards == 0 {
        return Err(kom_accel::Error::Usage("lint: batch and shards must be >= 1".into()));
    }
    let per_shard = batch.div_ceil(shards);
    let inst = NetworkInstance::random(Network::build(kind), 42)?;
    let mut drv = Driver::new(SocConfig::serving());
    drv.set_fusion(fuse);
    let dep = inst.deploy_batched(&mut drv, per_shard)?;
    println!(
        "{}: {} layer(s), batch {batch} over {shards} shard(s) ({per_shard}/shard), fusion {}",
        inst.net.name,
        dep.descs.len(),
        if fuse { "on" } else { "off" }
    );

    let diags = drv.lint_table(&dep.descs, per_shard as u32);
    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    let warns = diags.len() - errors;
    for d in &diags {
        println!("  {d}");
    }

    let bounds = verify::cycle_lower_bounds(&dep.descs, per_shard as u32, drv.soc.config());
    let mut t = Table::new(&["layer", "kind", "compute >=", "mem >="]);
    for (i, (d, (c, m))) in dep.descs.iter().zip(&bounds).enumerate() {
        let kind = match d {
            LayerDesc::Conv { .. } => "conv",
            LayerDesc::Pool { .. } => "pool",
            LayerDesc::Fc { .. } => "fc",
            LayerDesc::Fir { .. } => "fir",
            LayerDesc::End => "end",
        };
        t.row(vec![i.to_string(), kind.to_string(), c.to_string(), m.to_string()]);
    }
    println!("{}", t.to_ascii());
    println!(
        "lint: {errors} error(s), {warns} warning(s) over {} layer(s)",
        dep.descs.len()
    );
    if errors > 0 || (deny_warnings && warns > 0) {
        std::process::exit(1);
    }
    Ok(())
}

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("tables") => cmd_tables(&args),
        Some("timing") => cmd_timing(),
        Some("emit") => cmd_emit(&args),
        Some("wave") => cmd_wave(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("golden") => cmd_golden(&args),
        Some("serve") => cmd_serve(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("lint") => cmd_lint(&args),
        Some("trace") => cmd_trace(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
