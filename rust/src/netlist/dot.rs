//! Graphviz DOT emitter — the "RTL schematic" view (paper Fig 4).

use super::{Driver, Gate, Netlist};
use std::fmt::Write as _;

/// Render the netlist as a DOT digraph (one node per gate, rank-ordered by
/// logic depth). Intended for small modules; the CLI caps it at 5k nets.
pub fn to_dot(nl: &Netlist) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "digraph \"{}\" {{", nl.name);
    let _ = writeln!(s, "  rankdir=LR; node [fontsize=9, shape=box];");
    for (id, d) in nl.iter() {
        let label = match d {
            Driver::Input => "IN".to_string(),
            Driver::Gate(g) => match g {
                Gate::Const(b) => format!("{}", *b as u8),
                Gate::Buf(_) => "BUF".into(),
                Gate::Not(_) => "NOT".into(),
                Gate::And(..) => "AND".into(),
                Gate::Or(..) => "OR".into(),
                Gate::Xor(..) => "XOR".into(),
                Gate::Nand(..) => "NAND".into(),
                Gate::Nor(..) => "NOR".into(),
                Gate::Xnor(..) => "XNOR".into(),
                Gate::Mux(..) => "MUX".into(),
                Gate::Maj(..) => "MAJ".into(),
                Gate::Xor3(..) => "XOR3".into(),
                Gate::Dff(..) => "DFF".into(),
            },
        };
        let shape = match d {
            Driver::Input => ", shape=ellipse, style=filled, fillcolor=lightblue",
            Driver::Gate(Gate::Dff(..)) => ", style=filled, fillcolor=lightyellow",
            _ => "",
        };
        let _ = writeln!(s, "  n{} [label=\"{}\"{}];", id.0, label, shape);
        if let Driver::Gate(g) = d {
            for i in g.inputs() {
                let _ = writeln!(s, "  n{} -> n{};", i.0, id.0);
            }
        }
    }
    let _ = writeln!(s, "}}");
    s
}

#[cfg(test)]
mod tests {
    use crate::netlist::Netlist;

    #[test]
    fn emits_digraph() {
        let mut nl = Netlist::new("g");
        let a = nl.input_bus("a", 1);
        let x = nl.not(a[0]);
        nl.output_bus("o", &vec![x]);
        let d = super::to_dot(&nl);
        assert!(d.starts_with("digraph"));
        assert!(d.contains("NOT"));
        assert!(d.contains("->"));
    }
}
