//! External DRAM latency/bandwidth model.
//!
//! Each burst pays a fixed row-activation latency plus words/width cycles.
//! Weights and activations for the large VGG layers live here; the DMA
//! engine streams them into the scratchpad.

use crate::error::{Error, Result};

/// External memory model (word addressed, i64 payload).
pub struct Dram {
    data: Vec<i64>,
    /// Fixed cycles per burst (row activate + CAS).
    pub burst_latency: u64,
    /// Words transferred per cycle once streaming.
    pub words_per_cycle: u64,
    /// Total cycles spent in DRAM traffic.
    pub cycles: u64,
    /// Total words moved.
    pub words_moved: u64,
}

impl Dram {
    /// `words` capacity with a default DDR-ish profile.
    pub fn new(words: usize) -> Self {
        Dram {
            data: vec![0; words],
            burst_latency: 30,
            words_per_cycle: 4,
            cycles: 0,
            words_moved: 0,
        }
    }

    /// Capacity in words.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn check(&self, addr: usize, len: usize) -> Result<()> {
        if addr + len > self.data.len() {
            return Err(Error::Accel(format!(
                "dram access [{addr}, {}) beyond {} words",
                addr + len,
                self.data.len()
            )));
        }
        Ok(())
    }

    /// Cycles one `len`-word burst costs (row activate + streaming) —
    /// the cost model behind [`Dram::read_burst`]/[`Dram::write_burst`],
    /// exposed so the pipelined SoC can price a prospective prefetch
    /// without moving data.
    pub fn burst_cost(&self, len: usize) -> u64 {
        self.burst_latency + (len as u64).div_ceil(self.words_per_cycle)
    }

    fn charge(&mut self, len: usize) {
        self.cycles += self.burst_cost(len);
        self.words_moved += len as u64;
    }

    /// Burst read.
    pub fn read_burst(&mut self, addr: usize, len: usize) -> Result<Vec<i64>> {
        self.check(addr, len)?;
        self.charge(len);
        Ok(self.data[addr..addr + len].to_vec())
    }

    /// Burst write.
    pub fn write_burst(&mut self, addr: usize, values: &[i64]) -> Result<()> {
        self.check(addr, values.len())?;
        self.charge(values.len());
        self.data[addr..addr + values.len()].copy_from_slice(values);
        Ok(())
    }

    /// Host-side (zero-cost) initialisation, e.g. loading weights at boot.
    pub fn preload(&mut self, addr: usize, values: &[i64]) -> Result<()> {
        self.check(addr, values.len())?;
        self.data[addr..addr + values.len()].copy_from_slice(values);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_cycle_model() {
        let mut d = Dram::new(1024);
        d.write_burst(0, &vec![5; 64]).unwrap();
        assert_eq!(d.cycles, 30 + 16);
        let v = d.read_burst(0, 64).unwrap();
        assert_eq!(v[0], 5);
        assert_eq!(d.cycles, 2 * (30 + 16));
        assert_eq!(d.words_moved, 128);
    }

    #[test]
    fn preload_is_free() {
        let mut d = Dram::new(8);
        d.preload(0, &[1, 2, 3]).unwrap();
        assert_eq!(d.cycles, 0);
        assert_eq!(d.read_burst(0, 3).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn bounds() {
        let mut d = Dram::new(4);
        assert!(d.read_burst(2, 3).is_err());
    }
}
