//! Dynamic batching: group requests under a max-size / max-wait policy
//! ([`Batcher`], the fixed fill-to-max batcher), or admit them into the
//! next dispatch the moment a worker is free, sized against a latency SLO
//! ([`ContinuousBatcher`] + [`SloPolicy`]).
//!
//! The continuous batcher never waits for company: whatever is queued
//! when a worker asks is dispatched immediately, and the *size* of that
//! dispatch comes from the scheduler's measured cycles/request EMA
//! converted to simulated microseconds — the largest batch whose
//! predicted queue-wait + execution still meets the p99 target.

use super::request::InferenceRequest;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Maximum time the first request of a batch waits for company.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// SLO-aware dynamic sizing for the continuous batcher. Pure arithmetic —
/// no channels, no clocks — so the same policy drives the threaded
/// coordinator (wall-clock waits) and the simulated-time load generator
/// ([`crate::coordinator::loadgen`]) identically.
#[derive(Clone, Copy, Debug)]
pub struct SloPolicy {
    /// Maximum requests per dispatch (the deployed batch capacity).
    pub max_batch: usize,
    /// Replicas the worker shards each batch across: an `n`-request batch
    /// executes in `ceil(n / shards)` per-replica sub-batches running
    /// concurrently, so predicted execution is
    /// `ceil(n / shards) × us_per_req`.
    pub shards: usize,
    /// Simulated accelerator clock (MHz) — converts the scheduler's
    /// cycles/request EMA into simulated microseconds.
    pub clock_mhz: f64,
    /// p99 latency target in simulated microseconds. `None` = pure
    /// continuous batching: take everything queued up to `max_batch`,
    /// never shrink, never shed.
    pub slo_p99_us: Option<u64>,
}

impl SloPolicy {
    /// The EMA converted to simulated microseconds per request
    /// (truncating: the scheduler's cold EMA of 1 cycle maps to 0us, so a
    /// cold policy never shrinks or sheds — it learns from the first
    /// completed batches).
    pub fn us_per_req(&self, ema_cycles_per_req: u64) -> u64 {
        (ema_cycles_per_req as f64 / self.clock_mhz) as u64
    }

    /// Predicted execution time of an `n`-request batch in simulated
    /// microseconds: the shards run concurrently, so the batch costs its
    /// largest per-replica sub-batch.
    pub fn exec_us(&self, n: usize, ema_cycles_per_req: u64) -> u64 {
        n.div_ceil(self.shards.max(1)) as u64 * self.us_per_req(ema_cycles_per_req)
    }

    /// Is the SLO attainable at all under the learned EMA — does a single
    /// request dispatched alone, with zero queue wait, meet the target?
    /// When this is false no batch-size choice can help, and the front
    /// door sheds via the `overloaded` path instead of queueing work that
    /// is already doomed. Always true without an SLO, and true for a cold
    /// (unlearned) EMA.
    pub fn attainable(&self, ema_cycles_per_req: u64) -> bool {
        match self.slo_p99_us {
            None => true,
            Some(slo) => self.us_per_req(ema_cycles_per_req) <= slo,
        }
    }

    /// Dynamic batch size for a dispatch with `queued` requests waiting,
    /// the oldest of which has already waited `oldest_wait_us`: the
    /// largest `n <= min(queued, max_batch)` whose predicted
    /// wait + execution stays inside the SLO. Never 0 — a free worker
    /// with queued work always dispatches. If even a single request can
    /// no longer meet the target (the oldest already overstayed), the SLO
    /// is lost either way, so the policy reverts to throughput-optimal
    /// `min(queued, max_batch)` rather than dribbling out singletons.
    pub fn batch_size(&self, queued: usize, oldest_wait_us: u64, ema_cycles_per_req: u64) -> usize {
        let cap = queued.clamp(1, self.max_batch.max(1));
        let Some(slo) = self.slo_p99_us else {
            return cap;
        };
        for n in (1..=cap).rev() {
            if oldest_wait_us + self.exec_us(n, ema_cycles_per_req) <= slo {
                return n;
            }
        }
        cap
    }
}

/// Pulls requests from the front-door channel and forms batches.
pub struct Batcher {
    rx: Receiver<InferenceRequest>,
    policy: BatchPolicy,
}

impl Batcher {
    /// New batcher over the submission channel.
    pub fn new(rx: Receiver<InferenceRequest>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { rx, policy }
    }

    /// Block for the next batch. `None` when the channel is closed and
    /// drained (shutdown).
    pub fn next_batch(&self) -> Option<Vec<InferenceRequest>> {
        // block for the batch's first request
        let first = self.rx.recv().ok()?;
        let deadline = Instant::now() + self.policy.max_wait;
        let mut batch = vec![first];
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // saturating: `now` can pass `deadline` between the check
            // above and this subtraction — a plain `deadline - now` would
            // panic on the underflow
            match self.rx.recv_timeout(deadline.saturating_duration_since(now)) {
                Ok(req) => batch.push(req),
                // `recv_timeout` may report Timeout slightly early on
                // loaded machines; only the deadline check at the top of
                // the loop decides when the partial batch flushes
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

/// Continuous batcher: the worker-facing replacement for the fixed
/// [`Batcher`]. Instead of filling a fixed-size batch on a timeout, a
/// free worker takes whatever is queued *right now* — blocking only when
/// there is nothing at all — and [`SloPolicy::batch_size`] decides how
/// much of it rides in this dispatch. Requests the policy leaves behind
/// stay in the backlog, first in line for the next dispatch.
pub struct ContinuousBatcher {
    rx: Receiver<InferenceRequest>,
    backlog: VecDeque<InferenceRequest>,
    policy: SloPolicy,
}

impl ContinuousBatcher {
    /// New continuous batcher over the submission channel.
    pub fn new(rx: Receiver<InferenceRequest>, policy: SloPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        ContinuousBatcher {
            rx,
            backlog: VecDeque::new(),
            policy,
        }
    }

    /// The sizing policy.
    pub fn policy(&self) -> &SloPolicy {
        &self.policy
    }

    /// Requests pulled off the channel but not yet dispatched.
    pub fn backlog_len(&self) -> usize {
        self.backlog.len()
    }

    /// Block until at least one request is available, admit everything
    /// already queued (up to `max_batch`) without waiting for more, and
    /// size the dispatch from the caller's cycles/request EMA. `None`
    /// when the channel is closed and the backlog drained (shutdown).
    pub fn next_batch(&mut self, ema_cycles_per_req: u64) -> Option<Vec<InferenceRequest>> {
        if self.backlog.is_empty() {
            match self.rx.recv() {
                Ok(req) => self.backlog.push_back(req),
                Err(_) => return None,
            }
        }
        // admit whatever has already arrived — never wait for company
        while self.backlog.len() < self.policy.max_batch {
            match self.rx.try_recv() {
                Ok(req) => self.backlog.push_back(req),
                Err(_) => break, // empty now, or disconnected (next recv says which)
            }
        }
        let oldest_wait_us = self
            .backlog
            .front()
            .map(|r| r.submitted.elapsed().as_micros() as u64)
            .unwrap_or(0);
        let n = self
            .policy
            .batch_size(self.backlog.len(), oldest_wait_us, ema_cycles_per_req);
        Some(self.backlog.drain(..n).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::tensor::Tensor;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64, reply: mpsc::Sender<super::super::request::InferenceResponse>) -> InferenceRequest {
        InferenceRequest {
            id,
            input: Tensor::zeros(vec![1]),
            submitted: Instant::now(),
            reply,
        }
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        let (rtx, _rrx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i, rtx.clone())).unwrap();
        }
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
            },
        );
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "FIFO within batch");
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        // wide tolerances so a loaded CI machine cannot flake this: the
        // wait is 25ms and we only assert the lower bound at 20ms (the
        // batcher never flushes a partial batch before its deadline; no
        // upper bound is asserted because the scheduler owes us nothing)
        let (tx, rx) = mpsc::channel();
        let (rtx, _rrx) = mpsc::channel();
        tx.send(req(0, rtx)).unwrap();
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(25),
            },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1, "partial batch must flush");
        assert!(
            t0.elapsed() >= Duration::from_millis(20),
            "flushed after {:?}, before the max-wait window",
            t0.elapsed()
        );
    }

    #[test]
    fn zero_max_wait_flushes_immediately_without_panicking() {
        // regression: with max_wait = 0 the deadline equals (or precedes)
        // `now` on entry, so the old `deadline - now` subtraction inside
        // the recv_timeout call could underflow-panic if the clock ticked
        // between the loop's deadline check and the subtraction. The
        // saturating form must flush the partial batch instead.
        let (tx, rx) = mpsc::channel();
        let (rtx, _rrx) = mpsc::channel();
        for i in 0..3 {
            tx.send(req(i, rtx.clone())).unwrap();
        }
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::ZERO,
            },
        );
        let batch = b.next_batch().expect("first request forms a batch");
        assert!(!batch.is_empty() && batch.len() <= 8);
        assert_eq!(batch[0].id, 0, "FIFO from the channel");
    }

    #[test]
    fn none_on_shutdown() {
        let (tx, rx) = mpsc::channel::<InferenceRequest>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    fn policy(max_batch: usize, shards: usize, slo: Option<u64>) -> SloPolicy {
        SloPolicy {
            max_batch,
            shards,
            clock_mhz: 200.0,
            slo_p99_us: slo,
        }
    }

    #[test]
    fn slo_policy_converts_ema_to_simulated_time() {
        let p = policy(16, 4, Some(1000));
        // 200 MHz: 200 cycles per microsecond
        assert_eq!(p.us_per_req(20_000), 100);
        assert_eq!(p.exec_us(1, 20_000), 100);
        assert_eq!(p.exec_us(4, 20_000), 100, "4 shards run 4 requests concurrently");
        assert_eq!(p.exec_us(5, 20_000), 200, "the 5th spills into a second wave");
        assert_eq!(p.exec_us(16, 20_000), 400);
        // the cold EMA (1 cycle) truncates to 0us: nothing shrinks or
        // sheds before the first real completion is learned
        assert_eq!(p.us_per_req(1), 0);
        assert!(p.attainable(1));
    }

    #[test]
    fn slo_policy_sizes_against_the_target() {
        // ema 20_000 cycles -> 100us/request; 4 shards
        let ema = 20_000;
        // no SLO: pure continuous, take everything up to max_batch
        assert_eq!(policy(16, 4, None).batch_size(7, 0, ema), 7);
        assert_eq!(policy(16, 4, None).batch_size(40, 123, ema), 16);
        // loose SLO (4 waves fit): coalesce to max_batch
        assert_eq!(policy(16, 4, Some(400)).batch_size(16, 0, ema), 16);
        // tight SLO (one wave fits): shrink to one wave of 4
        assert_eq!(policy(16, 4, Some(150)).batch_size(16, 0, ema), 4);
        // queue wait eats budget: 250us waited of 400 leaves one wave
        assert_eq!(policy(16, 4, Some(400)).batch_size(16, 250, ema), 4);
        // a free worker with queued work always dispatches at least 1
        assert_eq!(policy(16, 4, Some(100)).batch_size(3, 0, ema), 3);
        assert_eq!(policy(16, 4, Some(100)).batch_size(1, 0, ema), 1);
        // oldest already blew the budget: SLO is lost either way, revert
        // to throughput-optimal rather than dribbling singletons
        assert_eq!(policy(16, 4, Some(400)).batch_size(16, 401, ema), 16);
        // attainability: a lone request meeting the target
        assert!(policy(16, 4, Some(100)).attainable(ema));
        assert!(!policy(16, 4, Some(99)).attainable(ema));
        assert!(policy(16, 4, None).attainable(ema));
    }

    #[test]
    fn continuous_batcher_takes_what_is_queued_without_waiting() {
        let (tx, rx) = mpsc::channel();
        let (rtx, _rrx) = mpsc::channel();
        for i in 0..6 {
            tx.send(req(i, rtx.clone())).unwrap();
        }
        let mut b = ContinuousBatcher::new(rx, policy(4, 1, None));
        let t0 = Instant::now();
        let batch = b.next_batch(1).unwrap();
        assert_eq!(batch.len(), 4, "capped at max_batch");
        assert_eq!(
            batch.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3],
            "FIFO"
        );
        // no max-wait window exists to sleep through
        assert!(
            t0.elapsed() < Duration::from_millis(250),
            "continuous admission must not wait for company"
        );
        // the leftovers lead the next dispatch
        let batch = b.next_batch(1).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 5]);
    }

    #[test]
    fn continuous_batcher_keeps_slo_leftovers_in_backlog() {
        let (tx, rx) = mpsc::channel();
        let (rtx, _rrx) = mpsc::channel();
        for i in 0..8 {
            tx.send(req(i, rtx.clone())).unwrap();
        }
        // 100ms/request on 4 shards, 150ms target -> one 4-wide wave
        // fits, two never do; the 50ms of slack absorbs any wall-clock
        // queue wait a loaded CI machine charges the oldest request
        // before dispatch
        let ema = 20_000_000;
        let mut b = ContinuousBatcher::new(rx, policy(8, 4, Some(150_000)));
        let batch = b.next_batch(ema).unwrap();
        assert_eq!(batch.len(), 4, "SLO shrinks the dispatch to one wave");
        assert_eq!(b.backlog_len(), 4, "the rest stays queued, not dropped");
        drop(tx);
        // backlog drains before shutdown is reported
        let batch = b.next_batch(ema).unwrap();
        assert!(!batch.is_empty());
        let mut rest: Vec<u64> = batch.iter().map(|r| r.id).collect();
        while let Some(more) = b.next_batch(ema) {
            rest.extend(more.iter().map(|r| r.id));
        }
        assert_eq!(rest, vec![4, 5, 6, 7], "backlog drains in order before shutdown");
        assert_eq!(b.backlog_len(), 0);
    }

    #[test]
    fn continuous_batcher_none_on_shutdown() {
        let (tx, rx) = mpsc::channel::<InferenceRequest>();
        drop(tx);
        let mut b = ContinuousBatcher::new(rx, policy(8, 1, None));
        assert!(b.next_batch(1).is_none());
    }
}
