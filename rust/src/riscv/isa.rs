//! RV32I instruction decoding.

use crate::error::{Error, Result};

/// Decoded RV32I instruction (the subset the control programs use).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Instr {
    /// Load upper immediate.
    Lui { rd: u8, imm: i32 },
    /// Add upper immediate to PC.
    Auipc { rd: u8, imm: i32 },
    /// Jump and link.
    Jal { rd: u8, imm: i32 },
    /// Jump and link register.
    Jalr { rd: u8, rs1: u8, imm: i32 },
    /// Conditional branch; `funct3` selects eq/ne/lt/ge/ltu/geu.
    Branch { funct3: u8, rs1: u8, rs2: u8, imm: i32 },
    /// Load word.
    Lw { rd: u8, rs1: u8, imm: i32 },
    /// Store word.
    Sw { rs1: u8, rs2: u8, imm: i32 },
    /// Register-immediate ALU op (`funct3` + `sra` flag for SRAI).
    OpImm { funct3: u8, rd: u8, rs1: u8, imm: i32, funct7: u8 },
    /// Register-register ALU op.
    Op { funct3: u8, funct7: u8, rd: u8, rs1: u8, rs2: u8 },
    /// Environment call (halts the control program).
    Ecall,
    /// MUL (M extension, used by address arithmetic in control programs).
    Mul { rd: u8, rs1: u8, rs2: u8 },
}

fn bits(word: u32, lo: u32, hi: u32) -> u32 {
    (word >> lo) & ((1u32 << (hi - lo + 1)) - 1)
}

fn sext(v: u32, width: u32) -> i32 {
    let shift = 32 - width;
    ((v << shift) as i32) >> shift
}

/// Decode a 32-bit instruction word.
pub fn decode(word: u32) -> Result<Instr> {
    let opcode = bits(word, 0, 6);
    let rd = bits(word, 7, 11) as u8;
    let funct3 = bits(word, 12, 14) as u8;
    let rs1 = bits(word, 15, 19) as u8;
    let rs2 = bits(word, 20, 24) as u8;
    let funct7 = bits(word, 25, 31) as u8;
    Ok(match opcode {
        0b0110111 => Instr::Lui { rd, imm: (word & 0xFFFF_F000) as i32 },
        0b0010111 => Instr::Auipc { rd, imm: (word & 0xFFFF_F000) as i32 },
        0b1101111 => {
            let imm = (bits(word, 31, 31) << 20)
                | (bits(word, 12, 19) << 12)
                | (bits(word, 20, 20) << 11)
                | (bits(word, 21, 30) << 1);
            Instr::Jal { rd, imm: sext(imm, 21) }
        }
        0b1100111 => Instr::Jalr { rd, rs1, imm: sext(bits(word, 20, 31), 12) },
        0b1100011 => {
            let imm = (bits(word, 31, 31) << 12)
                | (bits(word, 7, 7) << 11)
                | (bits(word, 25, 30) << 5)
                | (bits(word, 8, 11) << 1);
            Instr::Branch { funct3, rs1, rs2, imm: sext(imm, 13) }
        }
        0b0000011 if funct3 == 0b010 => {
            Instr::Lw { rd, rs1, imm: sext(bits(word, 20, 31), 12) }
        }
        0b0100011 if funct3 == 0b010 => {
            let imm = (bits(word, 25, 31) << 5) | bits(word, 7, 11);
            Instr::Sw { rs1, rs2, imm: sext(imm, 12) }
        }
        0b0010011 => Instr::OpImm {
            funct3,
            rd,
            rs1,
            imm: sext(bits(word, 20, 31), 12),
            funct7,
        },
        0b0110011 if funct7 == 1 && funct3 == 0 => Instr::Mul { rd, rs1, rs2 },
        0b0110011 => Instr::Op { funct3, funct7, rd, rs1, rs2 },
        0b1110011 if word == 0x0000_0073 => Instr::Ecall,
        _ => {
            return Err(Error::Riscv(format!(
                "illegal instruction {word:#010x} (opcode {opcode:#09b})"
            )))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_addi() {
        // addi x1, x0, 42
        let w = (42u32 << 20) | (0 << 15) | (0 << 12) | (1 << 7) | 0b0010011;
        // funct7 aliases the immediate's top bits and is only meaningful
        // for shift ops — don't assert it here
        match decode(w).unwrap() {
            Instr::OpImm { funct3: 0, rd: 1, rs1: 0, imm: 42, .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn decode_negative_imm() {
        // addi x2, x1, -1
        let w = (0xFFFu32 << 20) | (1 << 15) | (2 << 7) | 0b0010011;
        match decode(w).unwrap() {
            Instr::OpImm { imm, .. } => assert_eq!(imm, -1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn decode_ecall_and_illegal() {
        assert_eq!(decode(0x0000_0073).unwrap(), Instr::Ecall);
        assert!(decode(0xFFFF_FFFF).is_err());
    }

    #[test]
    fn jal_roundtrip_via_asm() {
        let w = crate::riscv::asm::enc_jal(1, -8);
        match decode(w).unwrap() {
            Instr::Jal { rd, imm } => {
                assert_eq!(rd, 1);
                assert_eq!(imm, -8);
            }
            other => panic!("{other:?}"),
        }
    }
}
