//! Static plan verifier: known-bad descriptor corpora must yield their
//! exact `KOM-Exxx` diagnostic codes, `Driver::compile` must provably
//! reject Error-level plans, and every shipped mini network must lint
//! clean at serving batch sizes with fusion on and off.

use kom_accel::accel::desc::FUSION_ENC_VERSION;
use kom_accel::accel::verify::{self, codes};
use kom_accel::accel::{Diagnostic, Driver, FusionCtl, FusionPlan, LayerDesc, SocConfig};
use kom_accel::cnn::networks::{Network, NetworkInstance, NetworkKind};
use kom_accel::Error;

/// The fusion planner's own test pair: fc1 (4→32) chained into fc2
/// (32→8), weights packed below the activation arena.
fn fc_pair() -> Vec<LayerDesc> {
    vec![
        LayerDesc::Fc {
            n_in: 4,
            n_out: 32,
            w_addr: 100,
            b_addr: 612,
            in_addr: 0,
            out_addr: 1000,
            relu: true,
            out_shift: 8,
        },
        LayerDesc::Fc {
            n_in: 32,
            n_out: 8,
            w_addr: 700,
            b_addr: 956,
            in_addr: 1000,
            out_addr: 2000,
            relu: false,
            out_shift: 8,
        },
    ]
}

fn small_cfg() -> SocConfig {
    SocConfig {
        cells: 64,
        ctrl_ram_words: 4096,
        dram_words: 1 << 16,
        spad_words: 4096,
        spad_banks: 8,
    }
}

fn codes_of(diags: &[Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.code).collect()
}

fn expect_plan_verify(err: Error, code: &str) {
    match err {
        Error::PlanVerify(diags) => assert!(
            diags.iter().any(|d| d.code == code),
            "expected {code} among {diags:?}"
        ),
        e => panic!("expected Error::PlanVerify, got: {e}"),
    }
}

#[test]
fn overlapping_weights_yield_e001_and_compile_rejects() {
    let mut descs = fc_pair();
    // drop the consumer's weight matrix inside the producer's live output
    // region [1000, 1032)
    let LayerDesc::Fc { w_addr, .. } = &mut descs[1] else {
        unreachable!()
    };
    *w_addr = 1010;
    let diags = verify::verify_table(&descs, 1, &small_cfg());
    assert!(
        codes_of(&diags).contains(&codes::OVERLAPPING_DRAM_REGIONS),
        "{diags:?}"
    );
    let mut drv = Driver::new(small_cfg());
    let err = drv.compile(&descs, 1).err().expect("compile must reject");
    expect_plan_verify(err, codes::OVERLAPPING_DRAM_REGIONS);
}

#[test]
fn weight_region_out_of_bounds_yields_e002() {
    let mut descs = fc_pair();
    let LayerDesc::Fc { w_addr, .. } = &mut descs[1] else {
        unreachable!()
    };
    *w_addr = (1 << 16) - 2; // 256-word matrix off the end of DRAM
    let diags = verify::verify_table(&descs, 1, &small_cfg());
    assert!(
        codes_of(&diags).contains(&codes::REGION_OUT_OF_BOUNDS),
        "{diags:?}"
    );
}

#[test]
fn broken_chain_yields_e003() {
    let mut descs = fc_pair();
    // intersects the producer's output region without matching it — a
    // corrupted chain, not an independent table
    let LayerDesc::Fc { in_addr, .. } = &mut descs[1] else {
        unreachable!()
    };
    *in_addr = 1004;
    let diags = verify::verify_table(&descs, 1, &small_cfg());
    assert!(
        codes_of(&diags).contains(&codes::BROKEN_DATAFLOW_CHAIN),
        "{diags:?}"
    );
    assert!(
        !codes_of(&diags).contains(&codes::UNCHAINED_LAYERS),
        "a broken chain is an error, not the disjoint-tables warning"
    );
}

#[test]
fn binding_inside_staging_bank_yields_e005() {
    let descs = fc_pair();
    // small_cfg: 512-word banks, so [0, 1024) is DMA staging territory
    let ctls = [
        FusionCtl {
            fuse_next: true,
            spad_binding: 100,
            resident_words: 32,
        },
        FusionCtl::none(),
    ];
    let diags = verify::verify_fusion(&descs, &ctls, &small_cfg());
    assert_eq!(
        codes_of(&diags),
        vec![codes::FUSION_BINDING_IN_STAGING_BANK],
        "{diags:?}"
    );
}

#[test]
fn budget_exceeded_by_one_word_yields_e006_and_compile_rejects() {
    let descs = fc_pair();
    let ctls = [
        FusionCtl {
            fuse_next: true,
            spad_binding: 16,
            resident_words: 32,
        },
        FusionCtl::none(),
    ];
    // 312 words / 39 banks → 8-word banks, 16 words of staging, 296-word
    // budget: resident 32 + consumer weights 264 fits exactly
    let fits = SocConfig {
        cells: 64,
        ctrl_ram_words: 4096,
        dram_words: 1 << 16,
        spad_words: 312,
        spad_banks: 39,
    };
    assert!(verify::verify_fusion(&descs, &ctls, &fits).is_empty());
    // one budget word less (banks still 8 words) → over by exactly one
    let tight = SocConfig {
        spad_words: 311,
        spad_banks: 38,
        ..fits
    };
    let diags = verify::verify_fusion(&descs, &ctls, &tight);
    assert_eq!(
        codes_of(&diags),
        vec![codes::FUSION_BUDGET_EXCEEDED],
        "{diags:?}"
    );
    // the honest planner never emits these bindings, but an explicit
    // fusion plan submitted through the escape hatch is still gated
    let mut drv = Driver::new(tight);
    let err = drv
        .compile_with_fusion(&descs, 1, &FusionPlan::from_ctls(&ctls))
        .err()
        .expect("compile_with_fusion must reject");
    expect_plan_verify(err, codes::FUSION_BUDGET_EXCEEDED);
}

#[test]
fn bad_sideband_version_yields_e008() {
    let descs = fc_pair();
    let ctls = [
        FusionCtl {
            fuse_next: true,
            spad_binding: 1024,
            resident_words: 32,
        },
        FusionCtl::none(),
    ];
    let mut image = Vec::new();
    for (d, ctl) in descs.iter().zip(&ctls) {
        let mut w = d.encode();
        ctl.encode_into(&mut w);
        image.extend_from_slice(&w);
    }
    image.extend_from_slice(&LayerDesc::End.encode());
    assert!(verify::verify_image(&descs, &ctls, &image).is_empty());

    let mut bad = image.clone();
    bad[13] = ((FUSION_ENC_VERSION + 1) << 8) | 1;
    let diags = verify::verify_image(&descs, &ctls, &bad);
    assert_eq!(
        codes_of(&diags),
        vec![codes::BAD_FUSION_SIDEBAND_VERSION],
        "{diags:?}"
    );
}

#[test]
fn corrupted_descriptor_word_yields_e007() {
    let descs = fc_pair();
    let ctls = [FusionCtl::none(), FusionCtl::none()];
    let mut image = Vec::new();
    for d in &descs {
        image.extend_from_slice(&d.encode());
    }
    image.extend_from_slice(&LayerDesc::End.encode());
    let mut bad = image.clone();
    bad[3] ^= 1; // flip one geometry bit in the first block
    let diags = verify::verify_image(&descs, &ctls, &bad);
    assert!(
        codes_of(&diags).contains(&codes::ENCODING_MISMATCH),
        "{diags:?}"
    );
}

#[test]
fn shipped_networks_lint_clean() {
    for name in ["tiny", "alexnet-mini", "vgg-mini"] {
        let kind = NetworkKind::parse(name).unwrap();
        let inst = NetworkInstance::random(Network::build(kind), 42).unwrap();
        for batch in [1usize, 8] {
            for fuse in [true, false] {
                let mut drv = Driver::new(SocConfig::serving());
                drv.set_fusion(fuse);
                let dep = inst.deploy_batched(&mut drv, batch).unwrap();
                let diags = drv.lint_table(&dep.descs, batch as u32);
                assert!(
                    diags.is_empty(),
                    "{name} batch {batch} fusion {fuse}: {diags:?}"
                );
            }
        }
    }
}
