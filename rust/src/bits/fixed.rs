//! Fixed-point formats used by the quantised CNN path.

use std::fmt;

/// A Q-format descriptor: `total_bits` two's-complement bits with
/// `frac_bits` fractional bits (e.g. Q8.8 = 16 total, 8 frac).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct QFormat {
    /// Total width in bits (including sign).
    pub total_bits: u32,
    /// Fractional bits.
    pub frac_bits: u32,
}

impl QFormat {
    /// Construct a format; panics on zero/overwide formats.
    pub const fn new(total_bits: u32, frac_bits: u32) -> Self {
        assert!(total_bits >= 2 && total_bits <= 64);
        assert!(frac_bits < total_bits);
        QFormat { total_bits, frac_bits }
    }

    /// Q8.8 — the default activation/weight format of the accelerator.
    pub const Q8_8: QFormat = QFormat::new(16, 8);
    /// Q16.16 — the wide accumulator-facing format.
    pub const Q16_16: QFormat = QFormat::new(32, 16);

    /// Smallest representable value.
    pub fn min_value(&self) -> i64 {
        -(1i64 << (self.total_bits - 1))
    }

    /// Largest representable value.
    pub fn max_value(&self) -> i64 {
        (1i64 << (self.total_bits - 1)) - 1
    }

    /// Scale factor (2^frac).
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }
}

/// A fixed-point number: raw integer + format.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Fixed {
    /// Raw two's-complement integer payload.
    pub raw: i64,
    /// Format of `raw`.
    pub fmt: QFormat,
}

impl Fixed {
    /// Quantise an f64 into `fmt`, saturating at the format bounds.
    pub fn from_f64(v: f64, fmt: QFormat) -> Self {
        let scaled = (v * fmt.scale()).round();
        let raw = if scaled.is_nan() {
            0
        } else {
            scaled.clamp(fmt.min_value() as f64, fmt.max_value() as f64) as i64
        };
        Fixed { raw, fmt }
    }

    /// Back to f64.
    pub fn to_f64(&self) -> f64 {
        self.raw as f64 / self.fmt.scale()
    }

    /// Saturating add in the same format.
    pub fn sat_add(&self, other: &Fixed) -> Fixed {
        assert_eq!(self.fmt, other.fmt);
        let sum = (self.raw as i128 + other.raw as i128)
            .clamp(self.fmt.min_value() as i128, self.fmt.max_value() as i128);
        Fixed { raw: sum as i64, fmt: self.fmt }
    }

    /// Full-precision multiply: result format doubles width and frac bits
    /// (Q8.8 × Q8.8 → Q16.16), matching the accelerator datapath.
    pub fn mul_full(&self, other: &Fixed) -> Fixed {
        assert_eq!(self.fmt, other.fmt);
        let f = QFormat::new(
            (self.fmt.total_bits * 2).min(64),
            self.fmt.frac_bits * 2,
        );
        Fixed { raw: self.raw * other.raw, fmt: f }
    }

    /// Requantise to a narrower format with round-to-nearest and saturation.
    pub fn requantize(&self, fmt: QFormat) -> Fixed {
        let shift = self.fmt.frac_bits as i64 - fmt.frac_bits as i64;
        let v = if shift > 0 {
            // round-to-nearest-even-free: add half ulp then arithmetic shift
            let half = 1i64 << (shift - 1);
            (self.raw + half) >> shift
        } else {
            self.raw << (-shift)
        };
        Fixed {
            raw: v.clamp(fmt.min_value(), fmt.max_value()),
            fmt,
        }
    }
}

impl fmt::Display for Fixed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}q{}.{}", self.to_f64(), self.fmt.total_bits - self.fmt.frac_bits, self.fmt.frac_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantise_roundtrip() {
        let f = QFormat::Q8_8;
        for v in [-0.5, 0.0, 1.25, 3.14159, -100.0, 127.99] {
            let q = Fixed::from_f64(v, f);
            assert!((q.to_f64() - v).abs() <= 0.5 / f.scale() + 1e-12, "v={v} q={q}");
        }
    }

    #[test]
    fn saturation() {
        let f = QFormat::Q8_8;
        assert_eq!(Fixed::from_f64(1e9, f).raw, f.max_value());
        assert_eq!(Fixed::from_f64(-1e9, f).raw, f.min_value());
    }

    #[test]
    fn mul_widens() {
        let f = QFormat::Q8_8;
        let a = Fixed::from_f64(2.5, f);
        let b = Fixed::from_f64(-4.0, f);
        let p = a.mul_full(&b);
        assert_eq!(p.fmt, QFormat::Q16_16);
        assert!((p.to_f64() - -10.0).abs() < 1e-3);
    }

    #[test]
    fn requantize_rounds() {
        let wide = Fixed { raw: 3 << 7, fmt: QFormat::new(32, 16) }; // 3 * 2^-9
        let narrow = wide.requantize(QFormat::Q8_8);
        // 3*2^-9 = 0.00586 -> nearest Q8.8 is 2 (0.0078) or 1 (0.0039); 1.5 rounds up to 2
        assert_eq!(narrow.raw, 2);
    }
}
