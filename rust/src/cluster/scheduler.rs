//! Shard dispatch policies: which replica runs which shard.
//!
//! Two policies (Shen et al.'s resource-partitioned processors need the
//! same placement decision):
//!
//! * **round-robin** — rotate through replicas in order, stateless beyond
//!   the rotation cursor; optimal when every shard costs the same,
//! * **least-outstanding-cycles** — send each shard to the replica with
//!   the least in-flight work. Replicas are identical, so in-flight
//!   *requests* order the same as in-flight *cycles*; the sort key is
//!   kept in request units, and a cycles-per-request EMA learned from
//!   completed runs converts the view to cycles for reporting
//!   ([`Scheduler::outstanding_cycles`]). With equal shards the two
//!   policies agree; under uneven shards or staggered completion the
//!   least-outstanding policy avoids stacking work on a busy replica.

use super::plan::ShardPlan;
use crate::error::{Error, Result};

/// Dispatch policy for assigning shards to replicas.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Rotate through replicas in index order.
    RoundRobin,
    /// Pick the replica with the least estimated outstanding cycles.
    LeastOutstandingCycles,
}

impl SchedulePolicy {
    /// Parse a CLI name (`rr`/`round-robin`, `loc`/`least-outstanding`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" => SchedulePolicy::RoundRobin,
            "loc" | "least-outstanding" | "least-outstanding-cycles" => {
                SchedulePolicy::LeastOutstandingCycles
            }
            other => return Err(Error::Usage(format!("unknown schedule policy '{other}'"))),
        })
    }
}

/// Stateful shard→replica scheduler over a fixed replica set.
#[derive(Debug)]
pub struct Scheduler {
    policy: SchedulePolicy,
    next_rr: usize,
    /// Requests currently in flight per replica.
    in_flight: Vec<u64>,
    /// EMA of accelerator cycles per request, learned from completions
    /// (starts at 1 so the first plan still orders replicas sensibly).
    cycles_per_req: u64,
    /// Cumulative completed cycles per replica (busy time, for reports).
    busy_cycles: Vec<u64>,
    /// Per-replica quarantine: `Some(end)` bars the replica from
    /// placement until the scheduler's cycle clock reaches `end` *and* a
    /// health probe readmits it. `None` = healthy.
    quarantined: Vec<Option<u64>>,
    /// Scheduler-local cycle clock, advanced by completed work — the
    /// time base probation is measured against.
    clock: u64,
}

impl Scheduler {
    /// Scheduler over `replicas` identical accelerators.
    pub fn new(policy: SchedulePolicy, replicas: usize) -> Result<Self> {
        if replicas == 0 {
            return Err(Error::Cluster("scheduler over 0 replicas".into()));
        }
        Ok(Scheduler {
            policy,
            next_rr: 0,
            in_flight: vec![0; replicas],
            cycles_per_req: 1,
            busy_cycles: vec![0; replicas],
            quarantined: vec![None; replicas],
            clock: 0,
        })
    }

    /// The configured policy.
    pub fn policy(&self) -> SchedulePolicy {
        self.policy
    }

    /// Replica count this scheduler places onto.
    pub fn replicas(&self) -> usize {
        self.in_flight.len()
    }

    /// Estimated outstanding cycles per replica.
    pub fn outstanding_cycles(&self) -> Vec<u64> {
        self.in_flight
            .iter()
            .map(|&reqs| reqs * self.cycles_per_req)
            .collect()
    }

    /// Cumulative completed cycles per replica.
    pub fn busy_cycles(&self) -> &[u64] {
        &self.busy_cycles
    }

    /// Bar a replica from placement for `probation_cycles` of completed
    /// cluster work. After probation it stays barred until a successful
    /// health probe calls [`Scheduler::readmit`].
    pub fn quarantine(&mut self, replica: usize, probation_cycles: u64) {
        if let Some(q) = self.quarantined.get_mut(replica) {
            *q = Some(self.clock.saturating_add(probation_cycles));
        }
    }

    /// Is the replica currently barred from placement?
    pub fn is_quarantined(&self, replica: usize) -> bool {
        self.quarantined.get(replica).is_some_and(|q| q.is_some())
    }

    /// Has a quarantined replica served out its cycle-based probation
    /// (making it eligible for a health probe)? `false` for healthy
    /// replicas.
    pub fn probation_over(&self, replica: usize) -> bool {
        self.quarantined
            .get(replica)
            .and_then(|q| *q)
            .is_some_and(|end| self.clock >= end)
    }

    /// Readmit a replica after a successful health probe.
    pub fn readmit(&mut self, replica: usize) {
        if let Some(q) = self.quarantined.get_mut(replica) {
            *q = None;
        }
    }

    /// Replicas currently quarantined.
    pub fn quarantined_replicas(&self) -> Vec<usize> {
        (0..self.quarantined.len())
            .filter(|&r| self.quarantined[r].is_some())
            .collect()
    }

    /// Replicas currently eligible for placement.
    pub fn healthy_count(&self) -> usize {
        self.quarantined.iter().filter(|q| q.is_none()).count()
    }

    /// The healthy replica with the least in-flight work, skipping any in
    /// `exclude` (the replica whose shard just faulted must not retry
    /// onto itself). Ties go to the lowest index; `None` when every
    /// candidate is quarantined or excluded.
    pub fn pick_healthy(&self, exclude: &[usize]) -> Option<usize> {
        (0..self.in_flight.len())
            .filter(|&r| !self.is_quarantined(r) && !exclude.contains(&r))
            .min_by_key(|&r| (self.in_flight[r], r))
    }

    /// Assign every shard of `plan` to a distinct replica and mark the
    /// work in flight. Errors when the plan holds more shards than there
    /// are replicas (one shard's inputs would overwrite another's DRAM
    /// region on the shared replica).
    pub fn assign_plan(&mut self, plan: &ShardPlan) -> Result<Vec<usize>> {
        let n = self.in_flight.len();
        if plan.len() > self.healthy_count() {
            return Err(Error::Cluster(format!(
                "plan has {} shards but the cluster has {} healthy replicas of {n}",
                plan.len(),
                self.healthy_count()
            )));
        }
        let order: Vec<usize> = match self.policy {
            SchedulePolicy::RoundRobin => {
                let start = self.next_rr;
                self.next_rr = (self.next_rr + plan.len()) % n;
                (0..n).map(|i| (start + i) % n).collect()
            }
            SchedulePolicy::LeastOutstandingCycles => {
                let mut idx: Vec<usize> = (0..n).collect();
                // replicas are identical, so outstanding requests order
                // the same as outstanding cycles — the sort key stays in
                // request units and the learned cycles/request estimate
                // only converts the view for `outstanding_cycles()`.
                // Stable sort: ties go to the lowest replica index.
                idx.sort_by_key(|&r| self.in_flight[r]);
                idx
            }
        };
        // quarantined replicas drop out of the candidate order; with
        // nothing quarantined this filter is the identity, so the pinned
        // rotation behavior is unchanged
        let assignments: Vec<usize> = order
            .into_iter()
            .filter(|&r| !self.is_quarantined(r))
            .take(plan.len())
            .collect();
        for (shard, &r) in plan.shards.iter().zip(&assignments) {
            self.in_flight[r] += shard.len as u64;
        }
        Ok(assignments)
    }

    /// Retire a completed shard: `requests` leave the replica's in-flight
    /// count and `cycles` (the measured run cost) updates both the
    /// replica's busy time and the learned per-request estimate.
    pub fn complete(&mut self, replica: usize, requests: u64, cycles: u64) {
        self.retire(replica, requests);
        self.clock = self.clock.saturating_add(cycles);
        if let Some(b) = self.busy_cycles.get_mut(replica) {
            *b += cycles;
        }
        if requests > 0 {
            let observed = cycles / requests;
            // EMA with 1/4 weight on the new observation
            self.cycles_per_req = (self.cycles_per_req * 3 + observed).div_ceil(4);
        }
    }

    /// The learned cycles/request estimate (EMA with 1/4 weight on each
    /// new observation, starting at 1 until the first completion). The
    /// continuous batcher converts this to simulated microseconds to size
    /// dispatches against a latency SLO.
    pub fn cycles_per_req_ema(&self) -> u64 {
        self.cycles_per_req
    }

    /// Drop in-flight work without recording a completion — for failed or
    /// abandoned dispatches, so an error path cannot leak phantom load
    /// into future placement decisions. Busy time and the learned cycle
    /// estimate are untouched.
    pub fn retire(&mut self, replica: usize, requests: u64) {
        if let Some(f) = self.in_flight.get_mut(replica) {
            *f = f.saturating_sub(requests);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_rotates_across_plans() {
        let mut s = Scheduler::new(SchedulePolicy::RoundRobin, 4).unwrap();
        let one = ShardPlan::split(3, 1).unwrap();
        assert_eq!(s.assign_plan(&one).unwrap(), vec![0]);
        assert_eq!(s.assign_plan(&one).unwrap(), vec![1]);
        assert_eq!(s.assign_plan(&one).unwrap(), vec![2]);
        assert_eq!(s.assign_plan(&one).unwrap(), vec![3]);
        assert_eq!(s.assign_plan(&one).unwrap(), vec![0], "wraps");
        let two = ShardPlan::split(8, 2).unwrap();
        assert_eq!(s.assign_plan(&two).unwrap(), vec![1, 2]);
    }

    #[test]
    fn least_outstanding_prefers_idle_replicas() {
        let mut s = Scheduler::new(SchedulePolicy::LeastOutstandingCycles, 3).unwrap();
        let one = ShardPlan::split(4, 1).unwrap();
        // replica 0 takes 4 in-flight requests and never completes
        assert_eq!(s.assign_plan(&one).unwrap(), vec![0]);
        // the next singleton plans land on the idle replicas
        assert_eq!(s.assign_plan(&one).unwrap(), vec![1]);
        assert_eq!(s.assign_plan(&one).unwrap(), vec![2]);
        // everyone equally loaded → lowest index wins the tie
        assert_eq!(s.assign_plan(&one).unwrap(), vec![0]);
        // completing replica 1's work makes it the least loaded
        s.complete(1, 4, 400);
        assert_eq!(s.assign_plan(&one).unwrap(), vec![1]);
        assert_eq!(s.busy_cycles()[1], 400);
    }

    #[test]
    fn cycles_per_req_ema_tracks_completions() {
        let mut s = Scheduler::new(SchedulePolicy::RoundRobin, 2).unwrap();
        assert_eq!(s.cycles_per_req_ema(), 1, "cold estimate before any completion");
        let one = ShardPlan::split(4, 1).unwrap();
        s.assign_plan(&one).unwrap();
        // 4 requests at 400 cycles -> observed 100/request;
        // EMA = ceil((1*3 + 100) / 4) = 26
        s.complete(0, 4, 400);
        assert_eq!(s.cycles_per_req_ema(), 26);
        // repeated identical observations converge on the observation
        for _ in 0..32 {
            s.assign_plan(&one).unwrap();
            s.complete(0, 4, 400);
        }
        assert_eq!(s.cycles_per_req_ema(), 100);
        // failed dispatches retire without polluting the estimate
        s.assign_plan(&one).unwrap();
        s.retire(0, 4);
        assert_eq!(s.cycles_per_req_ema(), 100);
    }

    #[test]
    fn assignments_are_distinct_per_plan() {
        for policy in [SchedulePolicy::RoundRobin, SchedulePolicy::LeastOutstandingCycles] {
            let mut s = Scheduler::new(policy, 4).unwrap();
            let plan = ShardPlan::split(10, 4).unwrap();
            let asg = s.assign_plan(&plan).unwrap();
            let mut seen = asg.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), asg.len(), "{policy:?} duplicated a replica");
        }
    }

    #[test]
    fn retire_drops_in_flight_without_completion_side_effects() {
        let mut s = Scheduler::new(SchedulePolicy::LeastOutstandingCycles, 2).unwrap();
        assert_eq!(s.replicas(), 2);
        let plan = ShardPlan::split(6, 2).unwrap();
        let asg = s.assign_plan(&plan).unwrap();
        assert!(s.outstanding_cycles().iter().any(|&c| c > 0));
        // abandon the dispatch: in-flight drains, busy time stays zero
        for (shard, &r) in plan.shards.iter().zip(&asg) {
            s.retire(r, shard.len as u64);
        }
        assert!(s.outstanding_cycles().iter().all(|&c| c == 0));
        assert!(s.busy_cycles().iter().all(|&c| c == 0));
        // out-of-range replica is a no-op, not a panic
        s.retire(99, 1);
    }

    #[test]
    fn too_many_shards_rejected() {
        let mut s = Scheduler::new(SchedulePolicy::RoundRobin, 2).unwrap();
        let plan = ShardPlan::split(9, 3).unwrap();
        assert!(s.assign_plan(&plan).is_err());
    }

    #[test]
    fn quarantine_bars_placement_until_probation_and_readmission() {
        let mut s = Scheduler::new(SchedulePolicy::RoundRobin, 3).unwrap();
        s.quarantine(1, 1000);
        assert!(s.is_quarantined(1));
        assert_eq!(s.healthy_count(), 2);
        assert_eq!(s.quarantined_replicas(), vec![1]);
        assert!(!s.probation_over(1), "clock has not advanced yet");
        // placement skips the quarantined replica
        let one = ShardPlan::split(3, 1).unwrap();
        assert_eq!(s.assign_plan(&one).unwrap(), vec![0]);
        assert_eq!(s.assign_plan(&one).unwrap(), vec![2], "1 is barred");
        // two shards over two healthy replicas still place; three cannot
        s.retire(0, 3);
        s.retire(2, 3);
        let two = ShardPlan::split(6, 2).unwrap();
        let asg = s.assign_plan(&two).unwrap();
        assert!(!asg.contains(&1), "{asg:?}");
        let three = ShardPlan::split(9, 3).unwrap();
        assert!(s.assign_plan(&three).is_err(), "only 2 healthy replicas");
        // completed work advances the clock past probation
        s.complete(0, 3, 600);
        s.complete(2, 3, 600);
        assert!(s.probation_over(1), "1200 cycles ≥ 1000-cycle probation");
        assert!(s.is_quarantined(1), "probation alone does not readmit");
        s.readmit(1);
        assert!(!s.is_quarantined(1));
        assert_eq!(s.healthy_count(), 3);
    }

    #[test]
    fn pick_healthy_prefers_idle_and_respects_exclusions() {
        let mut s = Scheduler::new(SchedulePolicy::LeastOutstandingCycles, 3).unwrap();
        let one = ShardPlan::split(4, 1).unwrap();
        assert_eq!(s.assign_plan(&one).unwrap(), vec![0]);
        // 1 and 2 are idle; the faulted replica 1 is excluded
        assert_eq!(s.pick_healthy(&[1]), Some(2));
        s.quarantine(2, 100);
        assert_eq!(s.pick_healthy(&[1]), Some(0), "2 quarantined, 1 excluded");
        assert_eq!(s.pick_healthy(&[0, 1]), None, "nobody left");
    }

    #[test]
    fn zero_replicas_rejected() {
        assert!(Scheduler::new(SchedulePolicy::RoundRobin, 0).is_err());
    }

    #[test]
    fn policy_parse() {
        assert_eq!(SchedulePolicy::parse("rr").unwrap(), SchedulePolicy::RoundRobin);
        assert_eq!(
            SchedulePolicy::parse("least-outstanding").unwrap(),
            SchedulePolicy::LeastOutstandingCycles
        );
        assert!(SchedulePolicy::parse("bogus").is_err());
    }
}
