//! Levelized two-state cycle simulator.

use crate::bits::BitVec;
use crate::error::{Error, Result};
use crate::netlist::{Bus, Driver, Gate, NetId, Netlist};

/// Fast cycle-based simulator.
///
/// Usage: [`CycleSim::set_bus`] the inputs, [`CycleSim::settle`] to
/// propagate combinational logic, read outputs with [`CycleSim::get_bus`],
/// then [`CycleSim::step_clock`] to advance sequential state.
pub struct CycleSim<'a> {
    nl: &'a Netlist,
    /// Current value of every net.
    value: Vec<bool>,
    /// Next-state buffer for DFFs (net index -> pending value).
    dff_nets: Vec<(u32, u32)>, // (q net, d net)
    /// Cumulative toggle counts per net (for the power model).
    toggles: Vec<u64>,
    /// Number of settle() calls (activity denominator).
    settles: u64,
    track_activity: bool,
}

impl<'a> CycleSim<'a> {
    /// Build a simulator; validates the netlist first.
    pub fn new(nl: &'a Netlist) -> Result<Self> {
        nl.validate()?;
        let mut dff_nets = Vec::new();
        for (id, d) in nl.iter() {
            if let Driver::Gate(Gate::Dff(dn, _)) = d {
                dff_nets.push((id.0, dn.0));
            }
        }
        Ok(CycleSim {
            nl,
            value: vec![false; nl.num_nets()],
            dff_nets,
            toggles: Vec::new(),
            settles: 0,
            track_activity: false,
        })
    }

    /// Enable per-net toggle counting (used by `crate::power`).
    pub fn enable_activity(&mut self) {
        self.track_activity = true;
        self.toggles = vec![0u64; self.nl.num_nets()];
    }

    /// Drive one input net.
    pub fn set_net(&mut self, net: NetId, v: bool) {
        debug_assert!(matches!(self.nl.driver(net), Driver::Input));
        self.value[net.index()] = v;
    }

    /// Drive an input bus with a word value (LSB first).
    pub fn set_bus(&mut self, bus: &Bus, v: &BitVec) {
        assert_eq!(bus.len(), v.len(), "bus/value width mismatch");
        for (i, &net) in bus.iter().enumerate() {
            self.set_net(net, v.get(i));
        }
    }

    /// Read any net.
    pub fn get_net(&self, net: NetId) -> bool {
        self.value[net.index()]
    }

    /// Read a bus as a BitVec.
    pub fn get_bus(&self, bus: &Bus) -> BitVec {
        BitVec::from_bits(bus.iter().map(|&n| self.value[n.index()]))
    }

    /// Propagate combinational logic to a fixed point (single pass in
    /// topological/creation order — sufficient because combinational gates
    /// only reference earlier nets).
    pub fn settle(&mut self) {
        self.settles += 1;
        for (id, d) in self.nl.iter() {
            if let Driver::Gate(g) = d {
                if g.is_dff() {
                    continue; // holds latched state
                }
                let v = self.eval(g);
                let idx = id.index();
                if self.track_activity && self.value[idx] != v {
                    self.toggles[idx] += 1;
                }
                self.value[idx] = v;
            }
        }
    }

    /// Rising clock edge: latch every DFF from its D input.
    pub fn step_clock(&mut self) {
        // two-phase: read all Ds first, then commit (models simultaneity)
        let next: Vec<bool> = self
            .dff_nets
            .iter()
            .map(|&(_, d)| self.value[d as usize])
            .collect();
        for (&(q, _), v) in self.dff_nets.iter().zip(next) {
            let idx = q as usize;
            if self.track_activity && self.value[idx] != v {
                self.toggles[idx] += 1;
            }
            self.value[idx] = v;
        }
    }

    /// Reset all DFFs to their reset values and clear nets.
    pub fn reset(&mut self) {
        for v in self.value.iter_mut() {
            *v = false;
        }
        for (id, d) in self.nl.iter() {
            if let Driver::Gate(Gate::Dff(_, rst)) = d {
                self.value[id.index()] = *rst;
            }
        }
    }

    /// Per-net switching activity (toggles per settle), for `crate::power`.
    pub fn activity(&self) -> Result<Vec<f64>> {
        if !self.track_activity {
            return Err(Error::Sim("activity tracking not enabled".into()));
        }
        let n = self.settles.max(1) as f64;
        Ok(self.toggles.iter().map(|&t| t as f64 / n).collect())
    }

    fn eval(&self, g: &Gate) -> bool {
        let v = |n: NetId| self.value[n.index()];
        match *g {
            Gate::Const(b) => b,
            Gate::Buf(a) => v(a),
            Gate::Not(a) => !v(a),
            Gate::And(a, b) => v(a) & v(b),
            Gate::Or(a, b) => v(a) | v(b),
            Gate::Xor(a, b) => v(a) ^ v(b),
            Gate::Nand(a, b) => !(v(a) & v(b)),
            Gate::Nor(a, b) => !(v(a) | v(b)),
            Gate::Xnor(a, b) => !(v(a) ^ v(b)),
            Gate::Mux(s, a, b) => {
                if v(s) {
                    v(b)
                } else {
                    v(a)
                }
            }
            Gate::Maj(a, b, c) => (v(a) & v(b)) | (v(b) & v(c)) | (v(a) & v(c)),
            Gate::Xor3(a, b, c) => v(a) ^ v(b) ^ v(c),
            Gate::Dff(..) => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::Netlist;

    #[test]
    fn comb_eval() {
        let mut nl = Netlist::new("c");
        let a = nl.input_bus("a", 1);
        let b = nl.input_bus("b", 1);
        let x = nl.xor(a[0], b[0]);
        let y = nl.and(a[0], b[0]);
        nl.output_bus("s", &vec![x]);
        nl.output_bus("c", &vec![y]);
        let mut sim = CycleSim::new(&nl).unwrap();
        for (av, bv, s, c) in [(false, false, false, false), (true, false, true, false), (true, true, false, true)] {
            sim.set_net(a[0], av);
            sim.set_net(b[0], bv);
            sim.settle();
            assert_eq!(sim.get_net(x), s);
            assert_eq!(sim.get_net(y), c);
        }
    }

    #[test]
    fn counter_sequential() {
        // 1-bit toggle flip-flop
        let mut nl = Netlist::new("t");
        let en = nl.input_bus("en", 1);
        let q = nl.dff_placeholder();
        let nq = nl.xor(q, en[0]);
        nl.connect_backedge(q, nq).unwrap();
        nl.output_bus("q", &vec![q]);
        let mut sim = CycleSim::new(&nl).unwrap();
        sim.set_net(en[0], true);
        let mut seen = vec![];
        for _ in 0..4 {
            sim.settle();
            seen.push(sim.get_net(q));
            sim.step_clock();
        }
        assert_eq!(seen, vec![false, true, false, true]);
    }

    #[test]
    fn activity_counts() {
        let mut nl = Netlist::new("a");
        let a = nl.input_bus("a", 1);
        let x = nl.not(a[0]);
        nl.output_bus("y", &vec![x]);
        let mut sim = CycleSim::new(&nl).unwrap();
        sim.enable_activity();
        for i in 0..10 {
            sim.set_net(a[0], i % 2 == 0);
            sim.settle();
        }
        let act = sim.activity().unwrap();
        // x toggles every settle (alternating input)
        assert!(act[x.index()] > 0.8);
    }
}
