//! Multi-SoC cluster acceptance tests: sharded batches stay bit-exact
//! with the host reference, uneven tails lose no requests, zero shard
//! counts fail cleanly, and the scale-out speedup claim — total cluster
//! cycles are the **max over shards**, because replicas run concurrently
//! — is gated at ≥ 2× for 4 shards on a batch-16 Tiny run.

use kom_accel::accel::SocConfig;
use kom_accel::cluster::{Cluster, ClusterConfig, SchedulePolicy, Scheduler, ShardPlan};
use kom_accel::cnn::networks::{Network, NetworkInstance, NetworkKind};
use kom_accel::cnn::Tensor;
use kom_accel::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use std::time::Duration;

fn soc() -> SocConfig {
    SocConfig::serving()
}

fn tiny_instance() -> NetworkInstance {
    NetworkInstance::random(Network::build(NetworkKind::Tiny), 42).unwrap()
}

fn tiny_inputs(n: usize, seed: u64) -> Vec<Tensor> {
    (0..n)
        .map(|i| Tensor::random(vec![1, 16, 16], 127, seed + i as u64))
        .collect()
}

/// Run `inputs` as one sharded batch on a fresh cluster of `shards`
/// replicas and return (per-request outputs, cluster cycles) from the
/// **second** run — both paths warmed, so weight staging does not skew
/// the comparison either way.
fn sharded_cycles(
    inst: &NetworkInstance,
    inputs: &[Tensor],
    shards: usize,
) -> (Vec<Vec<i64>>, u64) {
    let mut cluster = Cluster::new(ClusterConfig {
        replicas: shards,
        soc: soc(),
    })
    .unwrap();
    let per_shard = inputs.len().div_ceil(shards);
    let cdep = inst.deploy_cluster(&mut cluster, per_shard).unwrap();
    let mut sched = Scheduler::new(SchedulePolicy::LeastOutstandingCycles, shards).unwrap();
    let slices: Vec<&[i64]> = inputs.iter().map(|t| t.data.as_slice()).collect();
    cdep.run_sharded(&mut cluster, &mut sched, &slices).unwrap(); // warm
    let (outs, m) = cdep.run_sharded(&mut cluster, &mut sched, &slices).unwrap();
    assert_eq!(m.requests() as usize, inputs.len());
    (outs, m.total_cycles())
}

#[test]
fn four_shards_at_least_2x_over_one_shard_on_batch16_tiny() {
    let inst = tiny_instance();
    let inputs = tiny_inputs(16, 4000);

    let (outs1, cycles1) = sharded_cycles(&inst, &inputs, 1);
    let (outs4, cycles4) = sharded_cycles(&inst, &inputs, 4);

    // same answers through one SoC and through four
    for (i, t) in inputs.iter().enumerate() {
        let want = inst.forward_ref(t).unwrap();
        assert_eq!(outs1[i], want.data, "request {i}, 1 shard");
        assert_eq!(outs4[i], want.data, "request {i}, 4 shards");
    }

    // the speedup claim: cluster cycles are max-over-shards, so four
    // replicas each running a quarter of the batch must cut the critical
    // path at least in half (fixed per-run control/reconfig overhead is
    // why it is not a clean 4×)
    let speedup = cycles1 as f64 / cycles4 as f64;
    assert!(
        speedup >= 2.0,
        "4-shard speedup {speedup:.2}× < 2× (1 shard: {cycles1} cycles, 4 shards: {cycles4})"
    );
}

#[test]
fn coordinator_sharded_dispatch_bit_exact_for_every_request() {
    let inst = tiny_instance();
    for shards in [2usize, 4] {
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                shards,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(2),
                },
                ..Default::default()
            },
            &inst,
        )
        .unwrap();
        let inputs = tiny_inputs(24, 8000);
        let rxs: Vec<_> = inputs
            .iter()
            .map(|t| coord.submit(t.clone()).unwrap())
            .collect();
        for ((id, rx), input) in rxs.into_iter().zip(&inputs) {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, id);
            assert!(resp.is_ok(), "{:?}", resp.error);
            let want = inst.forward_ref(input).unwrap();
            assert_eq!(
                resp.logits, want.data,
                "request {id} with {shards} shards ≡ forward_ref"
            );
            assert_eq!(resp.class, want.argmax());
        }
        let stats = coord.shutdown();
        assert_eq!(stats.count(), 24, "{shards} shards");
        assert!(stats.batches >= 1);
    }
}

#[test]
fn uneven_batch_over_shards_loses_no_requests() {
    // 7 requests over 3 shards: the plan is 3/2/2 and every request must
    // come back, in order, bit-exact
    let inst = tiny_instance();
    let inputs = tiny_inputs(7, 12000);
    let (outs, _) = sharded_cycles(&inst, &inputs, 3);
    assert_eq!(outs.len(), 7);
    for (i, t) in inputs.iter().enumerate() {
        let want = inst.forward_ref(t).unwrap();
        assert_eq!(outs[i], want.data, "request {i} of the uneven batch");
    }

    // the same shape through the coordinator front door: exactly 7
    // submissions against a 7-wide batch policy on 3 shards
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            shards: 3,
            batch: BatchPolicy {
                max_batch: 7,
                max_wait: Duration::from_millis(5),
            },
            ..Default::default()
        },
        &inst,
    )
    .unwrap();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|t| coord.submit(t.clone()).unwrap())
        .collect();
    let mut seen = std::collections::HashSet::new();
    for (id, rx) in rxs {
        let resp = rx.recv().expect("no request may be lost");
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert!(seen.insert(resp.id), "duplicate id {}", resp.id);
        assert_eq!(resp.id, id);
    }
    assert_eq!(seen.len(), 7);
    let stats = coord.shutdown();
    assert_eq!(stats.count(), 7);
}

#[test]
fn zero_shard_count_errors_cleanly() {
    // at every layer: the plan, the cluster, and the coordinator knob
    assert!(ShardPlan::split(8, 0).is_err());
    assert!(Cluster::new(ClusterConfig {
        replicas: 0,
        soc: soc()
    })
    .is_err());
    let inst = tiny_instance();
    let err = Coordinator::start(
        CoordinatorConfig {
            shards: 0,
            ..Default::default()
        },
        &inst,
    )
    .err()
    .expect("shards: 0 must be rejected");
    assert!(err.to_string().contains("shard"), "{err}");
}
