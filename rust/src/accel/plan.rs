//! Compiled execution plans: hoist per-run planning out of the hot path.
//!
//! Before this module, every [`super::driver::Driver::run_table_batch`]
//! re-ran the fusion planner, re-encoded the descriptor table, re-wrote
//! control RAM and re-generated the control program — even when the run
//! was byte-identical to the previous one, which is exactly what a serving
//! hot loop looks like. A [`CompiledPlan`] is the **plan-once /
//! execute-many** artifact that fixes this (the specialize-then-amortize
//! move of Shen et al.'s resource partitioning and the ahead-of-time
//! design-point compilation surveyed by Abdelouahab et al.):
//!
//! * the fusion plan and its descriptor side-band encoding,
//! * the fully encoded control-RAM image (layer blocks + `End` block) —
//!   warm executions whose identical image is already resident in control
//!   RAM skip the rewrite (byte-compared, see `Soc::load_table_image`),
//! * the §III control program,
//! * per-layer [`EngineConfig`](crate::systolic::EngineConfig)
//!   fingerprints (the configuration identities the engine's context
//!   cache will see) and the table's DRAM weight bindings — the regions
//!   whose host rewrite invalidates the plan.
//!
//! Plans are cached per driver in a bounded LRU ([`PlanCache`]) keyed by
//! [`PlanKey`] — descriptor-table content, batch, fusion setting and
//! scratchpad geometry — replacing the old unbounded `program_cache` that
//! was keyed only on `(n_layers, batch)` and survived `reset_arena`.
//! `reset_arena` clears the cache wholesale (a stale plan would reference
//! reused DRAM addresses); a host rewrite overlapping a plan's weight
//! bindings drops that plan (its layer fingerprints no longer describe
//! the DRAM contents). Plans are driver-independent values behind an
//! `Arc`, so a cluster compiles each distinct `(table, sub-batch)` once
//! and seeds every replica's cache with the shared artifact.
//!
//! When the execution tracer is armed, each cold compile and each static
//! verification pass drops a zero-cycle `PlanCompile` / `PlanVerify`
//! marker on the trace timeline (see [`crate::accel::trace`]), making
//! cold dispatches visible without charging simulated cycles.

use super::desc::{LayerDesc, DESC_WORDS};
use super::fusion::{FusionGroup, FusionPlan};
use crate::cache::{BoundedLru, CacheStats};
use crate::systolic::config::Fnv;

/// FNV-1a 64-bit over a `u32` word stream (descriptor images) — same
/// shared accumulator as [`crate::systolic::EngineConfig::fingerprint`].
pub(crate) fn fnv_words(words: &[u32]) -> u64 {
    let mut h = Fnv::new();
    h.u32s(words);
    h.finish()
}

/// Cache identity of a compiled plan: everything the compiled artifact
/// depends on. Two tables with identical descriptor encodings, batch,
/// fusion setting and scratchpad geometry compile to the identical plan —
/// which is what lets replicas of a cluster share one artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Fingerprint of the raw (side-band-free) descriptor encodings.
    pub table_fp: u64,
    /// Batch the control program pokes into the `BATCH` register.
    pub batch: u32,
    /// Was the fusion planner applied?
    pub fused: bool,
    /// Scratchpad words the fusion plan was sized against.
    pub spad_words: usize,
    /// Staging-bank words the fusion plan was sized against.
    pub bank_words: usize,
}

/// Raw (side-band-free) descriptor encodings of a table — the content a
/// plan's identity is derived from, and what a cache hit is byte-verified
/// against so a fingerprint collision can never serve the wrong plan.
pub(crate) fn encode_raw(descs: &[LayerDesc]) -> Vec<u32> {
    let mut words = Vec::with_capacity(descs.len() * DESC_WORDS);
    for d in descs {
        words.extend_from_slice(&d.encode());
    }
    words
}

impl PlanKey {
    /// Key for `descs` at `batch` under the given fusion/scratchpad
    /// parameters.
    pub fn new(
        descs: &[LayerDesc],
        batch: u32,
        fused: bool,
        spad_words: usize,
        bank_words: usize,
    ) -> Self {
        Self::from_raw(&encode_raw(descs), batch, fused, spad_words, bank_words)
    }

    /// Key from already-encoded raw descriptor words (avoids re-encoding
    /// when the caller needs the words too, as the compile path does).
    pub(crate) fn from_raw(
        raw_words: &[u32],
        batch: u32,
        fused: bool,
        spad_words: usize,
        bank_words: usize,
    ) -> Self {
        PlanKey {
            table_fp: fnv_words(raw_words),
            batch,
            fused,
            spad_words,
            bank_words,
        }
    }
}

/// The compile-once / execute-many artifact (see the module docs). Built
/// by `Driver::compile`, executed by `Driver::execute`, shared across
/// cluster replicas behind an `Arc`.
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    /// Cache identity.
    pub key: PlanKey,
    /// Layers in the table (excluding the `End` block).
    pub n_layers: usize,
    /// Batch baked into the control program.
    pub batch: u32,
    /// Raw (side-band-free) descriptor encodings the plan was compiled
    /// from — byte-verified on every cache hit, so a `table_fp` collision
    /// degrades to a recompile, never to executing the wrong plan.
    pub src_words: Vec<u32>,
    /// Encoded control-RAM image: every layer block plus the `End` block,
    /// fusion side-band applied. Warm executions whose identical image is
    /// already resident skip the control-RAM rewrite (byte-compared by
    /// `Soc::load_table_image`).
    pub table_words: Vec<u32>,
    /// Assembled §III control program.
    pub program: Vec<u32>,
    /// Maximal fused chains of the plan (reporting/deployment metadata).
    pub fusion_groups: Vec<FusionGroup>,
    /// Fused producer→consumer edges (intermediate round trips skipped).
    pub fused_edges: usize,
    /// DRAM bindings: every weight region the table stages, as
    /// `(addr, words)`. A host rewrite overlapping any of these drops the
    /// plan from the cache.
    pub weight_regions: Vec<(u32, u32)>,
    /// Per-layer engine-configuration fingerprints, computed from the
    /// DRAM weight contents at compile time through the same
    /// `LayerDesc::engine_config` builder the SoC executes — the
    /// configuration identities a warm run presents to the engine's
    /// context cache.
    pub layer_fingerprints: Vec<u64>,
    /// Warn-level diagnostics the static verifier attached at compile
    /// time (Error-level findings reject the plan instead). Surfaced per
    /// run as `RunMetrics::verify_warnings`.
    pub warnings: u32,
    /// Identity of the driver that compiled (or adopted) this plan;
    /// `Driver::execute` refuses a plan stamped by a different driver —
    /// its DRAM bindings describe someone else's address space. Cluster
    /// sharing goes through `Driver::seed_plan`, which re-stamps an
    /// adopted copy.
    pub(crate) owner: u64,
    /// Driver arena epoch at compile time; `Driver::execute` refuses a
    /// plan compiled against a since-reset arena.
    pub(crate) epoch: u64,
}

impl CompiledPlan {
    /// Does `[addr, addr+len)` overlap any of this plan's DRAM weight
    /// bindings?
    pub fn binds_region(&self, addr: u32, len: usize) -> bool {
        let (lo, hi) = (addr as u64, addr as u64 + len as u64);
        self.weight_regions
            .iter()
            .any(|&(a, l)| (a as u64) < hi && lo < a as u64 + l as u64)
    }
}

/// Bounded LRU cache of compiled plans (per driver): a [`BoundedLru`]
/// with a unit cost model (every plan counts 1 against
/// [`PlanCache::CAPACITY`]). Replaces the old unbounded `program_cache`:
/// cleared by `reset_arena`, per-plan invalidated by host weight
/// rewrites.
#[derive(Debug)]
pub struct PlanCache {
    lru: BoundedLru<PlanKey, std::sync::Arc<CompiledPlan>>,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache {
            lru: BoundedLru::new(Self::CAPACITY, |_, _| 1),
        }
    }
}

impl PlanCache {
    /// Maximum resident plans; the least recently used is evicted beyond
    /// this. Sixteen covers every (network, batch, fusion) combination a
    /// serving worker rotates through with room to spare, while bounding
    /// the driver's footprint.
    pub const CAPACITY: usize = 16;

    /// Look up a plan, refreshing its LRU position and counting the hit.
    pub fn get(&mut self, key: &PlanKey) -> Option<std::sync::Arc<CompiledPlan>> {
        self.lru.get(key).cloned()
    }

    /// Insert a freshly compiled plan, counting the compile and evicting
    /// the LRU entry beyond capacity.
    pub fn insert(&mut self, plan: std::sync::Arc<CompiledPlan>) {
        self.lru.insert(plan.key, plan);
    }

    /// Insert without counting a compile — used when a cluster seeds a
    /// replica's cache with a plan another replica compiled.
    pub fn seed(&mut self, plan: std::sync::Arc<CompiledPlan>) {
        self.lru.seed(plan.key, plan);
    }

    /// Drop every plan (arena reset: all DRAM bindings are invalid).
    pub fn clear(&mut self) {
        self.lru.clear();
    }

    /// Drop plans whose weight bindings overlap a rewritten host region.
    pub fn invalidate_region(&mut self, addr: u32, len: usize) {
        self.lru.retain(|_, p| !p.binds_region(addr, len));
    }

    /// Resident plan count.
    pub fn len(&self) -> usize {
        self.lru.len()
    }

    /// True when no plan is cached.
    pub fn is_empty(&self) -> bool {
        self.lru.is_empty()
    }

    /// `(cache hits, compiles)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        let s = self.lru.stats();
        (s.hits, s.insertions)
    }

    /// Full counter snapshot of the underlying [`BoundedLru`].
    pub fn cache_stats(&self) -> CacheStats {
        self.lru.stats()
    }

    /// Fraction of plan requests served from cache: `hits / (hits +
    /// compiles)`. 0.0 before the first request.
    pub fn hit_rate(&self) -> f64 {
        let (hits, compiles) = self.stats();
        let total = hits + compiles;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

/// Encode a descriptor table (plus its `End` block) into the control-RAM
/// image `Driver::compile` caches, applying the fusion side-band.
pub(crate) fn encode_table_image(descs: &[LayerDesc], plan: &FusionPlan) -> Vec<u32> {
    let mut out = Vec::with_capacity((descs.len() + 1) * DESC_WORDS);
    for (i, d) in descs.iter().chain(std::iter::once(&LayerDesc::End)).enumerate() {
        let mut words = d.encode();
        plan.ctl(i).encode_into(&mut words);
        out.extend_from_slice(&words);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn fir(in_addr: u32, taps_addr: u32) -> LayerDesc {
        LayerDesc::Fir {
            taps_addr,
            n_taps: 4,
            in_addr,
            n: 16,
            out_addr: 500,
        }
    }

    fn plan_with(key: PlanKey, weight_regions: Vec<(u32, u32)>) -> Arc<CompiledPlan> {
        Arc::new(CompiledPlan {
            key,
            n_layers: 1,
            batch: key.batch,
            src_words: Vec::new(),
            table_words: Vec::new(),
            program: Vec::new(),
            fusion_groups: Vec::new(),
            fused_edges: 0,
            weight_regions,
            layer_fingerprints: Vec::new(),
            warnings: 0,
            owner: 0,
            epoch: 0,
        })
    }

    #[test]
    fn plan_key_tracks_table_content_batch_and_fusion() {
        let a = PlanKey::new(&[fir(0, 100)], 4, false, 1024, 128);
        assert_eq!(a, PlanKey::new(&[fir(0, 100)], 4, false, 1024, 128));
        assert_ne!(a, PlanKey::new(&[fir(1, 100)], 4, false, 1024, 128), "content");
        assert_ne!(a, PlanKey::new(&[fir(0, 100)], 8, false, 1024, 128), "batch");
        assert_ne!(a, PlanKey::new(&[fir(0, 100)], 4, true, 1024, 128), "fusion");
        assert_ne!(a, PlanKey::new(&[fir(0, 100)], 4, false, 2048, 128), "geometry");
    }

    #[test]
    fn cache_is_lru_bounded_and_counts_hits() {
        let mut c = PlanCache::default();
        assert!(c.is_empty());
        let key = |b: u32| PlanKey::new(&[fir(0, 100)], b, false, 1024, 128);
        for b in 0..(PlanCache::CAPACITY as u32 + 4) {
            c.insert(plan_with(key(b + 1), Vec::new()));
        }
        assert_eq!(c.len(), PlanCache::CAPACITY, "bounded, unlike program_cache");
        assert!(c.get(&key(1)).is_none(), "oldest entries evicted");
        assert!(c.get(&key(PlanCache::CAPACITY as u32 + 4)).is_some());
        let (hits, compiles) = c.stats();
        assert_eq!((hits, compiles), (1, PlanCache::CAPACITY as u64 + 4));
        assert!((c.hit_rate() - 1.0 / (1.0 + compiles as f64)).abs() < 1e-12);
    }

    #[test]
    fn region_invalidation_drops_only_overlapping_plans() {
        let mut c = PlanCache::default();
        let k1 = PlanKey::new(&[fir(0, 100)], 1, false, 1024, 128);
        let k2 = PlanKey::new(&[fir(0, 200)], 1, false, 1024, 128);
        c.insert(plan_with(k1, vec![(100, 4)]));
        c.insert(plan_with(k2, vec![(200, 4)]));
        // an input-region rewrite (no weight overlap) drops nothing — the
        // serving hot path rewrites inputs every batch
        c.invalidate_region(0, 16);
        assert_eq!(c.len(), 2);
        // a weight rewrite drops exactly the plan bound to it
        c.invalidate_region(102, 1);
        assert_eq!(c.len(), 1);
        assert!(c.get(&k1).is_none());
        assert!(c.get(&k2).is_some());
        c.clear();
        assert!(c.is_empty());
    }

    #[test]
    fn table_image_covers_end_block_and_side_band() {
        let descs = vec![fir(0, 100)];
        let image = encode_table_image(&descs, &FusionPlan::none(descs.len()));
        assert_eq!(image.len(), 2 * DESC_WORDS, "layer block + End block");
        assert_eq!(LayerDesc::decode(&image[..DESC_WORDS]).unwrap(), descs[0]);
        assert_eq!(
            LayerDesc::decode(&image[DESC_WORDS..]).unwrap(),
            LayerDesc::End
        );
        // identical tables produce identical fingerprints, different ones
        // do not
        let again = encode_table_image(&descs, &FusionPlan::none(1));
        assert_eq!(fnv_words(&image), fnv_words(&again));
        let other = encode_table_image(&[fir(1, 100)], &FusionPlan::none(1));
        assert_ne!(fnv_words(&image), fnv_words(&other));
    }
}
